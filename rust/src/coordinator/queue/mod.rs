//! The batching service front end on the coordinator: a multi-producer
//! request queue in front of [`Coordinator::partition_repeated`] /
//! [`Coordinator::partition_store`]-shaped work, batching **individual
//! repetitions** from many requests onto the one shared
//! [`ExecutionCtx`] pool.
//!
//! [`Coordinator`]: crate::coordinator::service::Coordinator
//!
//! # Model
//!
//! A [`Request`] is (graph handle, [`PartitionConfig`], seeds, reply
//! channel): the graph handle is either an in-memory [`Arc<Graph>`] or
//! an on-disk shard directory — the semi-external design means both
//! flow through the same queue and the same scheduler. Producers call
//! [`BatchService::submit`] (blocks while the queue is full) or
//! [`BatchService::try_submit`] (returns [`SubmitError::Busy`]) from
//! any number of threads and get back a [`Ticket`] to wait on.
//!
//! A scheduler thread drains the queue and fans out *repetitions*, not
//! whole requests: each scheduling wave interleaves one repetition per
//! active request round-robin until the wave is pool-sized, and the
//! round-robin start rotates every wave, so a 1-seed request submitted
//! next to a 10-seed request rides an early wave instead of queueing
//! behind all ten repetitions — even when the wave is narrower than
//! the active request count (e.g. one worker). Results are reassembled
//! per request in seed order.
//!
//! # Determinism
//!
//! Every repetition is a pure function of (graph, config, seed) — the
//! crate-wide thread-count-invariance contract — so the same request
//! produces an [`Aggregate`] whose deterministic fields (runs, cuts,
//! blocks, aggregates) are byte-identical for **any worker count, any
//! submission order, and any interleaving with other requests**; only
//! the wall-clock `seconds`/`avg_seconds` fields vary
//! (`rust/tests/batch_queue.rs`).
//!
//! # Backpressure and shutdown
//!
//! The queue is bounded by [`ServiceConfig::max_pending`]: `submit`
//! blocks until a slot frees, `try_submit` reports `Busy`. Dropping
//! (or explicitly [`BatchService::shutdown`]-ing) the service is
//! graceful: already-accepted requests are drained to completion and
//! their tickets resolve; new submissions are refused with
//! [`SubmitError::ShutDown`]. A panicking repetition (e.g. an invalid
//! config) fails only its own request — the service, its pool, and
//! every other request keep going.

mod scheduler;
pub mod spec;

use crate::coordinator::service::Aggregate;
use crate::graph::csr::Graph;
use crate::partitioning::config::PartitionConfig;
use crate::util::exec::ExecutionCtx;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// Service knobs.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads of the one shared pool (0 = available
    /// parallelism) — the process-wide cap, exactly like
    /// [`Coordinator::new`](crate::coordinator::service::Coordinator::new).
    pub workers: usize,
    /// Bound on accepted-but-not-yet-scheduled requests; at the bound,
    /// [`BatchService::submit`] blocks and
    /// [`BatchService::try_submit`] returns [`SubmitError::Busy`].
    /// Clamped to at least 1.
    pub max_pending: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 0,
            max_pending: 16,
        }
    }
}

/// Where a request's topology lives. Both kinds flow through the same
/// queue; shard directories are opened by the scheduler on activation.
#[derive(Debug, Clone)]
pub enum GraphHandle {
    /// An in-memory graph, shared with the submitter.
    InMemory(Arc<Graph>),
    /// An on-disk shard directory (see [`crate::graph::store`]);
    /// partitioned through the out-of-core driver under the request
    /// config's memory budget.
    Shards(PathBuf),
}

/// One unit of client work: partition `graph` once per seed under
/// `config`, aggregated exactly like
/// [`Coordinator::partition_repeated`](crate::coordinator::service::Coordinator::partition_repeated).
#[derive(Debug, Clone)]
pub struct Request {
    /// Client-chosen label, echoed in errors and the `serve` output.
    pub id: String,
    pub graph: GraphHandle,
    pub config: PartitionConfig,
    /// One repetition per seed; must be non-empty.
    pub seeds: Vec<u64>,
}

/// Why a submission was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue is at `max_pending` (only from
    /// [`BatchService::try_submit`]; `submit` blocks instead).
    Busy,
    /// The service is shutting down and accepts no new requests.
    ShutDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy => write!(f, "service queue is full"),
            SubmitError::ShutDown => write!(f, "service is shutting down"),
        }
    }
}

/// A request that failed (bad config panicking in the partitioner, an
/// unopenable shard directory, I/O errors on the external path, ...).
#[derive(Debug, Clone)]
pub struct RequestError {
    pub id: String,
    pub message: String,
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request {:?}: {}", self.id, self.message)
    }
}

pub(crate) type Reply = Result<Aggregate, RequestError>;

/// Handle to one submitted request's eventual result.
#[derive(Debug)]
pub struct Ticket {
    id: String,
    rx: mpsc::Receiver<Reply>,
}

impl Ticket {
    /// The request id this ticket belongs to.
    pub fn id(&self) -> &str {
        &self.id
    }

    /// Block until the request completes (or fails). Requests already
    /// accepted are always drained — even across service shutdown — so
    /// this resolves rather than hangs.
    pub fn wait(self) -> Reply {
        match self.rx.recv() {
            Ok(reply) => reply,
            // Scheduler gone without replying (it panicked — it never
            // drops a live request otherwise): surface, don't hang.
            Err(_) => Err(RequestError {
                message: "batching service terminated before the request completed".to_string(),
                id: self.id,
            }),
        }
    }
}

pub(crate) struct QueueState {
    pub(crate) pending: VecDeque<(Request, mpsc::Sender<Reply>)>,
    pub(crate) shutting_down: bool,
    /// While paused the scheduler activates nothing new (in-flight
    /// waves still finish); shutdown overrides pause for draining.
    pub(crate) paused: bool,
}

pub(crate) struct QueueShared {
    pub(crate) state: Mutex<QueueState>,
    /// Producers wait here for a queue slot.
    pub(crate) not_full: Condvar,
    /// The scheduler waits here for work (or shutdown/resume).
    pub(crate) not_empty: Condvar,
    pub(crate) max_pending: usize,
}

/// Poison-tolerant lock (a panicking repetition is contained inside the
/// scheduler; the queue mutex itself must survive any caller panic).
pub(crate) fn lock(m: &Mutex<QueueState>) -> MutexGuard<'_, QueueState> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// The batching service front end. See the module docs.
pub struct BatchService {
    shared: Arc<QueueShared>,
    ctx: Arc<ExecutionCtx>,
    scheduler: Option<JoinHandle<()>>,
}

impl BatchService {
    /// Service owning a fresh pool of `config.workers` threads.
    pub fn new(config: ServiceConfig) -> Self {
        let workers = config.workers;
        Self::with_ctx(config, Arc::new(ExecutionCtx::new(workers)))
    }

    /// Service on an existing shared execution context (the
    /// coordinator handoff: one process pool through every phase of
    /// every request).
    pub fn with_ctx(config: ServiceConfig, ctx: Arc<ExecutionCtx>) -> Self {
        let shared = Arc::new(QueueShared {
            state: Mutex::new(QueueState {
                pending: VecDeque::new(),
                shutting_down: false,
                paused: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            max_pending: config.max_pending.max(1),
        });
        let scheduler = {
            let shared = shared.clone();
            let ctx = ctx.clone();
            std::thread::Builder::new()
                .name("sclap-batch-scheduler".to_string())
                .spawn(move || scheduler::scheduler_loop(&shared, &ctx))
                .expect("spawn batch scheduler")
        };
        BatchService {
            shared,
            ctx,
            scheduler: Some(scheduler),
        }
    }

    /// The shared execution context (pool + phase-timing sink).
    pub fn ctx(&self) -> &Arc<ExecutionCtx> {
        &self.ctx
    }

    /// Total worker count of the shared pool.
    pub fn worker_count(&self) -> usize {
        self.ctx.threads()
    }

    /// Enqueue a request, blocking while the bounded queue is at
    /// [`ServiceConfig::max_pending`].
    pub fn submit(&self, request: Request) -> Result<Ticket, SubmitError> {
        self.submit_inner(request, true)
    }

    /// Enqueue a request without blocking: [`SubmitError::Busy`] when
    /// the bounded queue is full.
    pub fn try_submit(&self, request: Request) -> Result<Ticket, SubmitError> {
        self.submit_inner(request, false)
    }

    fn submit_inner(&self, request: Request, block: bool) -> Result<Ticket, SubmitError> {
        let metrics = self.ctx.metrics();
        let (tx, rx) = mpsc::channel();
        let id = request.id.clone();
        let wait_start = std::time::Instant::now();
        let mut waited = false;
        let mut st = lock(&self.shared.state);
        loop {
            if st.shutting_down {
                return Err(SubmitError::ShutDown);
            }
            if st.pending.len() < self.shared.max_pending {
                break;
            }
            if !block {
                metrics.counter("queue_busy_rejections").inc();
                return Err(SubmitError::Busy);
            }
            waited = true;
            st = self
                .shared
                .not_full
                .wait(st)
                .unwrap_or_else(|p| p.into_inner());
        }
        if waited {
            metrics
                .histogram("queue_wait_us")
                .observe(wait_start.elapsed().as_micros() as u64);
        }
        st.pending.push_back((request, tx));
        metrics.counter("queue_submitted").inc();
        metrics.gauge("queue_depth").set(st.pending.len() as i64);
        drop(st);
        self.shared.not_empty.notify_all();
        Ok(Ticket { id, rx })
    }

    /// Stop activating new requests (in-flight repetitions finish;
    /// accepted requests stay queued and producers keep hitting the
    /// backpressure bound). For maintenance windows — and for making
    /// backpressure deterministic in tests.
    pub fn pause(&self) {
        lock(&self.shared.state).paused = true;
    }

    /// Undo [`BatchService::pause`].
    pub fn resume(&self) {
        lock(&self.shared.state).paused = false;
        self.shared.not_empty.notify_all();
    }

    /// Graceful shutdown: refuse new submissions, drain every accepted
    /// request (their tickets resolve), then stop the scheduler.
    /// Dropping the service does the same.
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for BatchService {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.shared.state);
            st.shutting_down = true;
        }
        // Wake the scheduler (to drain and exit) and any blocked
        // producers (to observe ShutDown).
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
        if let Some(handle) = self.scheduler.take() {
            let _ = handle.join();
        }
    }
}

impl std::fmt::Debug for BatchService {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BatchService")
            .field("workers", &self.ctx.threads())
            .field("max_pending", &self.shared.max_pending)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::karate_club;
    use crate::partitioning::config::Preset;

    fn karate_request(id: &str, k: usize, seeds: Vec<u64>) -> Request {
        Request {
            id: id.to_string(),
            graph: GraphHandle::InMemory(Arc::new(karate_club())),
            config: PartitionConfig::preset(Preset::CFast, k),
            seeds,
        }
    }

    #[test]
    fn one_request_round_trips() {
        let service = BatchService::new(ServiceConfig {
            workers: 2,
            max_pending: 4,
        });
        let t = service.submit(karate_request("r1", 2, vec![1, 2, 3])).unwrap();
        assert_eq!(t.id(), "r1");
        let agg = t.wait().expect("request succeeds");
        assert_eq!(agg.runs.len(), 3);
        let seeds: Vec<u64> = agg.runs.iter().map(|r| r.seed).collect();
        assert_eq!(seeds, vec![1, 2, 3]);
    }

    #[test]
    fn matches_serial_coordinator() {
        let g = Arc::new(karate_club());
        let config = PartitionConfig::preset(Preset::CFast, 2);
        let serial = crate::coordinator::service::Coordinator::new(2).partition_repeated(
            g.clone(),
            &config,
            &[5, 6, 7],
        );
        let service = BatchService::new(ServiceConfig::default());
        let agg = service
            .submit(Request {
                id: "x".into(),
                graph: GraphHandle::InMemory(g),
                config,
                seeds: vec![5, 6, 7],
            })
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(agg.best_cut, serial.best_cut);
        assert_eq!(agg.best_blocks, serial.best_blocks);
        for (a, b) in agg.runs.iter().zip(serial.runs.iter()) {
            assert_eq!((a.seed, a.cut, &a.blocks), (b.seed, b.cut, &b.blocks));
        }
    }

    #[test]
    fn empty_seed_list_fails_the_request_not_the_service() {
        let service = BatchService::new(ServiceConfig {
            workers: 1,
            max_pending: 4,
        });
        let bad = service.submit(karate_request("empty", 2, vec![])).unwrap();
        let err = bad.wait().unwrap_err();
        assert!(err.message.contains("no seeds"), "{err}");
        // service still serves
        let ok = service.submit(karate_request("ok", 2, vec![1])).unwrap();
        assert_eq!(ok.wait().unwrap().runs.len(), 1);
    }

    #[test]
    fn missing_shard_directory_fails_cleanly() {
        let service = BatchService::new(ServiceConfig::default());
        let t = service
            .submit(Request {
                id: "ghost".into(),
                graph: GraphHandle::Shards(PathBuf::from("/definitely/not/a/dir")),
                config: PartitionConfig::preset(Preset::CFast, 2),
                seeds: vec![1],
            })
            .unwrap();
        let err = t.wait().unwrap_err();
        assert_eq!(err.id, "ghost");
        assert!(err.message.contains("shard"), "{err}");
    }

    #[test]
    fn submit_after_shutdown_refused() {
        let service = BatchService::new(ServiceConfig::default());
        let shared = service.shared.clone();
        service.shutdown();
        // the shared state is marked; a late producer holding a clone of
        // the front end would observe ShutDown (exercised through the
        // internal path since the public handle is consumed)
        assert!(lock(&shared.state).shutting_down);
    }
}
