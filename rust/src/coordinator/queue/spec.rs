//! Request specs for the batching-service front ends: one request per
//! line, whitespace-separated `key=value` tokens. **Blank lines and
//! `#`-comment lines are skipped in every spec stream** — `serve`'s
//! stdin/`--requests` input and the TCP wire protocol
//! (`coordinator::net`) share this grammar and this parser, so the two
//! transports can never drift. Example stream —
//!
//! ```text
//! id=r1 graph=/tmp/web.graph k=8 preset=CFast seeds=1,2,3 output=/tmp/r1.txt
//! id=r2 shards=/tmp/web-shards k=4 reps=3 seed=5 memory-budget=1
//! id=r3 instance=tiny-rmat k=8 epsilon=0.05 parallel-coarsening=true
//! id=r4 instance=tiny-rmat k=8 race=CFast,UFast seeds=1,2 timeout_ms=60000
//! ```
//!
//! plus the matching one-JSON-line-per-request result rendering. The
//! rendered line contains **only deterministic fields** unless timing
//! is explicitly requested, so two `serve` runs over the same requests
//! — any worker count, any submission order — produce byte-identical
//! output lines (the property CI's serve smoke job compares).

use crate::coordinator::service::Aggregate;
use crate::partitioning::config::{PartitionConfig, Preset, CONFIG_OPTION_KEYS};
use crate::util::json::escape_json;

/// Where one request's topology comes from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestSource {
    /// A graph file (`graph=PATH`) loadable by `graph::io::load_path`.
    GraphFile(String),
    /// A named generator instance (`instance=NAME`).
    Instance(String),
    /// An on-disk shard directory (`shards=DIR`).
    Shards(String),
}

/// One parsed request line (pure data — materializing graphs and
/// submitting is the caller's job, so parsing stays I/O-free and
/// testable).
#[derive(Debug, Clone, PartialEq)]
pub struct RequestSpec {
    pub id: String,
    pub source: RequestSource,
    pub k: usize,
    pub preset: Preset,
    /// Explicit seed list (from `seeds=...`, or expanded from
    /// `reps=N seed=S`; default: the single seed 1).
    pub seeds: Vec<u64>,
    /// `(key, value)` pairs for [`PartitionConfig::apply_option`].
    pub config_options: Vec<(String, String)>,
    /// Optional path to write the best partition to.
    pub output: Option<String>,
    /// End-to-end deadline (`timeout_ms=N`, N ≥ 1): the service arms
    /// the request's cancel token at submission and a deadline that
    /// passes anywhere — queued, mid-repetition — cancels the request
    /// (`{"status":"cancelled","reason":"timeout"}`). Deliberately
    /// **not** cache-key material: a cache hit returns long before any
    /// plausible deadline, and two requests differing only in
    /// `timeout_ms` want the same partition.
    pub timeout_ms: Option<u64>,
    /// Ensemble race (`race=PresetA,PresetB[,...]`, two or more): each
    /// named preset becomes a racer config (the line's shared config
    /// options applied on top of each), the scheduler decides the
    /// winner on the first seed, and only the winner completes. The
    /// result line is byte-identical to requesting the winning preset
    /// alone — and race membership IS cache-key material (see
    /// `coordinator::net::cache`).
    pub race: Vec<Preset>,
    /// `explain=true`: attach the per-repetition quality report
    /// ([`crate::obs::QualityReport`]) to the result line as a trailing
    /// `"explain"` field. Observation-only — every other byte of the
    /// line is identical with the flag on or off — but it IS cache-key
    /// material (an explained response and a plain response are
    /// different bytes; see `coordinator::net::cache`).
    pub explain: bool,
}

impl RequestSpec {
    /// Materialize the partitioner configuration for this spec.
    pub fn build_config(&self) -> Result<PartitionConfig, String> {
        let mut config = PartitionConfig::preset(self.preset, self.k);
        for (key, value) in &self.config_options {
            config.apply_option(key, value)?;
        }
        Ok(config)
    }

    /// Racer configs for a `race=` spec, in race-list order (the
    /// deterministic tie-break order): each named preset with this
    /// line's shared `config_options` applied on top. Empty for plain
    /// requests; an option a racer's config rejects is an error.
    pub fn racer_configs(&self) -> Result<Vec<(String, PartitionConfig)>, String> {
        self.race
            .iter()
            .map(|p| {
                let mut config = PartitionConfig::preset(*p, self.k);
                for (key, value) in &self.config_options {
                    config.apply_option(key, value)?;
                }
                Ok((p.name().replace('/', ""), config))
            })
            .collect()
    }

    /// Render this spec as one canonical request line:
    /// `id= <source>= k= preset= [race=] seeds= [timeout_ms=]
    /// [explain=true] [config options…] [output=]`. Seeds are always
    /// explicit (a
    /// `reps=/seed=` shorthand parses into the same canonical list),
    /// and preset names are emitted without `/` separators so the line
    /// stays whitespace-token clean.
    /// `parse_request_line ∘ to_line` is the identity on valid specs —
    /// the round-trip property the unit tests enforce — which is what
    /// lets the network client re-emit parsed requests verbatim.
    pub fn to_line(&self) -> String {
        let (source_key, source_value) = match &self.source {
            RequestSource::GraphFile(p) => ("graph", p),
            RequestSource::Instance(n) => ("instance", n),
            RequestSource::Shards(d) => ("shards", d),
        };
        let seeds: Vec<String> = self.seeds.iter().map(|s| s.to_string()).collect();
        let mut line = format!(
            "id={} {source_key}={source_value} k={} preset={}",
            self.id,
            self.k,
            self.preset.name().replace('/', ""),
        );
        if !self.race.is_empty() {
            let racers: Vec<String> = self
                .race
                .iter()
                .map(|p| p.name().replace('/', ""))
                .collect();
            line.push_str(&format!(" race={}", racers.join(",")));
        }
        line.push_str(&format!(" seeds={}", seeds.join(",")));
        if let Some(ms) = self.timeout_ms {
            line.push_str(&format!(" timeout_ms={ms}"));
        }
        if self.explain {
            line.push_str(" explain=true");
        }
        for (key, value) in &self.config_options {
            line.push_str(&format!(" {key}={value}"));
        }
        if let Some(out) = &self.output {
            line.push_str(&format!(" output={out}"));
        }
        line
    }
}

/// Keys a request line may use besides [`CONFIG_OPTION_KEYS`].
const SPEC_KEYS: &[&str] = &[
    "id",
    "graph",
    "instance",
    "shards",
    "k",
    "preset",
    "seeds",
    "reps",
    "seed",
    "output",
    "timeout_ms",
    "race",
    "explain",
];

fn known_key(key: &str) -> bool {
    SPEC_KEYS.contains(&key) || CONFIG_OPTION_KEYS.contains(&key)
}

/// Parse one request line. `default_id` names the request when the line
/// has no `id=` (callers pass e.g. `"req3"` for line 3). Returns
/// `Ok(None)` for blank/comment lines; unknown keys, missing required
/// keys, and malformed values are errors — a service front end must
/// never silently ignore part of a request.
pub fn parse_request_line(line: &str, default_id: &str) -> Result<Option<RequestSpec>, String> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut id = default_id.to_string();
    let mut source: Option<RequestSource> = None;
    let mut k: Option<usize> = None;
    let mut preset_name = "CFast".to_string();
    let mut seeds: Option<Vec<u64>> = None;
    let mut reps: Option<usize> = None;
    let mut seed: Option<u64> = None;
    let mut output = None;
    let mut timeout_ms: Option<u64> = None;
    let mut race: Vec<Preset> = Vec::new();
    let mut explain = false;
    let mut config_options = Vec::new();
    let mut seen: Vec<String> = Vec::new();

    for token in line.split_whitespace() {
        let (key, value) = token
            .split_once('=')
            .ok_or_else(|| format!("bad token {token:?} (want key=value)"))?;
        if !known_key(key) {
            return Err(format!("unknown request key {key:?}"));
        }
        // Last-wins would silently ignore part of the request (e.g. two
        // specs pasted onto one line) — reject, like the CLI parser
        // rejects duplicate options.
        if seen.iter().any(|s| s == key) {
            return Err(format!("duplicate request key {key:?}"));
        }
        seen.push(key.to_string());
        let set_source = |source: &mut Option<RequestSource>, s: RequestSource| {
            if source.is_some() {
                return Err("more than one of graph=/instance=/shards=".to_string());
            }
            *source = Some(s);
            Ok(())
        };
        match key {
            "id" => id = value.to_string(),
            "graph" => set_source(&mut source, RequestSource::GraphFile(value.to_string()))?,
            "instance" => set_source(&mut source, RequestSource::Instance(value.to_string()))?,
            "shards" => set_source(&mut source, RequestSource::Shards(value.to_string()))?,
            "k" => {
                k = Some(
                    value
                        .parse()
                        .map_err(|_| format!("k: bad integer {value:?}"))?,
                );
            }
            "preset" => preset_name = value.to_string(),
            "seeds" => {
                let parsed: Result<Vec<u64>, _> =
                    value.split(',').map(|t| t.trim().parse::<u64>()).collect();
                seeds = Some(parsed.map_err(|_| format!("seeds: bad list {value:?}"))?);
            }
            "reps" => {
                reps = Some(
                    value
                        .parse()
                        .map_err(|_| format!("reps: bad integer {value:?}"))?,
                );
            }
            "seed" => {
                seed = Some(
                    value
                        .parse()
                        .map_err(|_| format!("seed: bad integer {value:?}"))?,
                );
            }
            "output" => output = Some(value.to_string()),
            "timeout_ms" => {
                let ms: u64 = value
                    .parse()
                    .map_err(|_| format!("timeout_ms: bad integer {value:?}"))?;
                if ms == 0 {
                    return Err("timeout_ms must be at least 1".to_string());
                }
                timeout_ms = Some(ms);
            }
            "race" => {
                for name in value.split(',') {
                    let name = name.trim();
                    race.push(
                        Preset::from_name(name)
                            .ok_or_else(|| format!("race: unknown preset {name:?}"))?,
                    );
                }
                if race.len() < 2 {
                    return Err("race needs at least two presets".to_string());
                }
            }
            "explain" => {
                explain = match value {
                    "true" => true,
                    "false" => false,
                    other => return Err(format!("explain: want true/false, got {other:?}")),
                };
            }
            // everything else is a config key by `known_key`
            other => config_options.push((other.to_string(), value.to_string())),
        }
    }

    let source = source.ok_or("need one of graph=/instance=/shards=")?;
    let k = k.ok_or("need k=")?;
    if k == 0 {
        return Err("k must be at least 1".to_string());
    }
    let preset = Preset::from_name(&preset_name)
        .ok_or_else(|| format!("unknown preset {preset_name:?}"))?;
    let seeds = match (seeds, reps, seed) {
        (Some(_), Some(_), _) => {
            return Err("seeds= and reps= are mutually exclusive".to_string())
        }
        (Some(_), None, Some(_)) => {
            return Err("seeds= and seed= are mutually exclusive".to_string())
        }
        (Some(list), None, None) => list,
        (None, r, s) => {
            let start = s.unwrap_or(1);
            let n = r.unwrap_or(1);
            (0..n as u64).map(|i| start + i).collect()
        }
    };
    if seeds.is_empty() {
        return Err("request has no seeds".to_string());
    }
    Ok(Some(RequestSpec {
        id,
        source,
        k,
        preset,
        seeds,
        config_options,
        output,
        timeout_ms,
        race,
        explain,
    }))
}

/// FNV-1a over the little-endian bytes of a block vector — a compact
/// deterministic fingerprint of a partition for result lines.
pub fn blocks_fingerprint(blocks: &[u32]) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x100_0000_01b3;
    let mut h = FNV_OFFSET;
    for b in blocks {
        for byte in b.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// Render one finished request as a JSON line. Field order is fixed and
/// every field is a pure function of the request — except the trailing
/// timing fields, emitted only when `timing` is set (they vary run to
/// run, so the default output is bit-for-bit reproducible).
pub fn render_result_line(id: &str, agg: &Aggregate, timing: bool) -> String {
    render_result_line_cached(id, agg, timing, false)
}

/// [`render_result_line`] with the service-layer cache marker: when
/// `cached` is set, a trailing `"cached":true` field records that the
/// aggregate came out of the content-addressed result cache
/// (`coordinator::net::cache`) instead of a fresh computation. A
/// non-cached line carries **no** `cached` field, so it stays
/// byte-identical to the offline `serve` rendering — the wire
/// determinism contract compares exactly these bytes.
pub fn render_result_line_cached(id: &str, agg: &Aggregate, timing: bool, cached: bool) -> String {
    render_result_line_full(id, agg, timing, cached, None)
}

/// [`render_result_line_cached`] with optional workspace lease stats
/// `(leases_created, peak_lease_bytes)` from the service context's
/// [`VcycleWorkspace`](crate::partitioning::workspace::VcycleWorkspace).
/// Like `avg_seconds` they are emitted **only when `timing` is set**
/// (they accumulate across the daemon's lifetime, so the default output
/// stays bit-for-bit reproducible — the wire determinism contract
/// compares exactly those bytes).
pub fn render_result_line_full(
    id: &str,
    agg: &Aggregate,
    timing: bool,
    cached: bool,
    workspace: Option<(u64, usize)>,
) -> String {
    let seeds: Vec<String> = agg.runs.iter().map(|r| r.seed.to_string()).collect();
    let cuts: Vec<String> = agg.runs.iter().map(|r| r.cut.to_string()).collect();
    let mut line = format!(
        "{{\"id\":\"{}\",\"status\":\"ok\",\"n\":{},\"reps\":{},\"seeds\":[{}],\"cuts\":[{}],\"avg_cut\":{},\"best_cut\":{},\"infeasible_runs\":{},\"best_blocks_fnv\":\"{:016x}\"",
        escape_json(id),
        agg.best_blocks.len(),
        agg.runs.len(),
        seeds.join(","),
        cuts.join(","),
        agg.avg_cut,
        agg.best_cut,
        agg.infeasible_runs,
        blocks_fingerprint(&agg.best_blocks),
    );
    // The explain payload is deterministic (worker-count- and
    // backend-invariant), so it renders before the timing gate: an
    // `explain=true` line without `timing` is still byte-reproducible.
    if let Some(explain) = &agg.explain {
        line.push_str(",\"explain\":");
        line.push_str(explain);
    }
    if timing {
        line.push_str(&format!(",\"avg_seconds\":{}", agg.avg_seconds));
        // Per-phase wall-clock breakdown (summed across the request's
        // repetitions). Names and order are deterministic — only the
        // seconds vary — and like every timing field it is gated so the
        // default line stays byte-reproducible.
        let phases: Vec<String> = agg
            .phase_seconds
            .iter()
            .map(|(name, s)| format!("{{\"name\":\"{name}\",\"seconds\":{s:.6}}}"))
            .collect();
        line.push_str(&format!(",\"phases\":[{}]", phases.join(",")));
        if let Some((leases_created, peak_lease_bytes)) = workspace {
            line.push_str(&format!(
                ",\"leases_created\":{leases_created},\"peak_lease_bytes\":{peak_lease_bytes}"
            ));
        }
    }
    if cached {
        line.push_str(",\"cached\":true");
    }
    line.push('}');
    line
}

/// Render one failed request as a JSON line.
pub fn render_error_line(id: &str, message: &str) -> String {
    format!(
        "{{\"id\":\"{}\",\"status\":\"error\",\"error\":\"{}\"}}",
        escape_json(id),
        escape_json(message)
    )
}

/// Render one refused request (bounded queue at `max_pending`) as a
/// JSON line — the wire protocol's structured backpressure signal
/// (`coordinator::net`: `try_submit → Busy` maps here instead of
/// blocking the connection).
pub fn render_busy_line(id: &str) -> String {
    format!("{{\"id\":\"{}\",\"status\":\"busy\"}}", escape_json(id))
}

/// Render one cancelled request as a JSON line: `reason` is the stable
/// wire string of
/// [`CancelReason::as_str`](crate::util::cancel::CancelReason::as_str)
/// (`timeout` / `disconnect` / `race_lost` / `abandoned`). Distinct
/// from [`render_error_line`] so clients can tell "the service chose
/// to stop" from "the request is broken".
pub fn render_cancelled_line(id: &str, reason: crate::util::cancel::CancelReason) -> String {
    format!(
        "{{\"id\":\"{}\",\"status\":\"cancelled\",\"reason\":\"{}\"}}",
        escape_json(id),
        reason.as_str()
    )
}

/// Write one block id per line to `out` (the `output=` request key and
/// the `partition --output` flag; quiet — callers report, because
/// `serve` must keep stdout pure JSON).
pub fn write_partition_file(out: &str, blocks: &[u32]) -> std::io::Result<()> {
    let mut text = String::new();
    for b in blocks {
        text.push_str(&b.to_string());
        text.push('\n');
    }
    std::fs::write(out, text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::RunOutcome;

    fn parse(line: &str) -> RequestSpec {
        parse_request_line(line, "d").unwrap().unwrap()
    }

    fn parse_err(line: &str) -> String {
        parse_request_line(line, "d").unwrap_err()
    }

    #[test]
    fn blank_and_comment_lines_skip() {
        assert!(parse_request_line("", "d").unwrap().is_none());
        assert!(parse_request_line("   ", "d").unwrap().is_none());
        assert!(parse_request_line("# graph=x k=2", "d").unwrap().is_none());
    }

    #[test]
    fn full_line_parses() {
        let s = parse("id=r1 graph=/tmp/g.graph k=8 preset=UFast seeds=3,1,2 output=/tmp/o.txt");
        assert_eq!(s.id, "r1");
        assert_eq!(s.source, RequestSource::GraphFile("/tmp/g.graph".into()));
        assert_eq!(s.k, 8);
        assert_eq!(s.preset, Preset::UFast);
        assert_eq!(s.seeds, vec![3, 1, 2]);
        assert_eq!(s.output.as_deref(), Some("/tmp/o.txt"));
    }

    #[test]
    fn defaults_and_reps_expansion() {
        let s = parse("instance=tiny-rmat k=4");
        assert_eq!(s.id, "d");
        assert_eq!(s.preset, Preset::CFast);
        assert_eq!(s.seeds, vec![1]);
        let s = parse("instance=tiny-rmat k=4 reps=3 seed=5");
        assert_eq!(s.seeds, vec![5, 6, 7]);
    }

    #[test]
    fn config_options_flow_into_the_config() {
        let s = parse("shards=/tmp/dir k=4 memory-budget=2k epsilon=0.05 parallel-coarsening=true");
        assert_eq!(s.source, RequestSource::Shards("/tmp/dir".into()));
        let c = s.build_config().unwrap();
        assert_eq!(c.memory_budget_bytes, Some(2048));
        assert!((c.epsilon - 0.05).abs() < 1e-12);
        assert!(c.parallel_coarsening);
        assert_eq!(c.k, 4);
    }

    #[test]
    fn errors_are_loud() {
        assert!(parse_err("k=4").contains("graph=/instance=/shards="));
        assert!(parse_err("graph=g").contains("need k="));
        assert!(parse_err("graph=g k=0").contains("at least 1"));
        assert!(parse_err("graph=g k=2 prest=UFast").contains("unknown request key"));
        assert!(parse_err("graph=g k=2 preset=Bogus").contains("unknown preset"));
        assert!(parse_err("graph=g k=2 seeds=1,x").contains("bad list"));
        assert!(parse_err("graph=g k=4 k=8").contains("duplicate request key"));
        assert!(parse_err("graph=g k=2 epsilon=0.01 epsilon=0.05").contains("duplicate"));
        assert!(parse_err("graph=g k=2 seeds=1 reps=2").contains("mutually exclusive"));
        assert!(parse_err("graph=g k=2 seeds=1 seed=2").contains("mutually exclusive"));
        assert!(parse_err("graph=g k=2 seeds=").contains("bad list"));
        assert!(parse_err("graph=g instance=x k=2").contains("more than one"));
        assert!(parse_err("graph=g k=2 bare-token").contains("key=value"));
        // config-key values are validated through apply_option
        let s = parse("graph=g k=2 memory-budget=1q");
        assert!(s.build_config().unwrap_err().contains("memory-budget"));
    }

    fn tiny_aggregate() -> Aggregate {
        let mk = |seed, cut| RunOutcome {
            seed,
            cut,
            seconds: 0.25,
            imbalance: 0.0,
            feasible: true,
            initial_cut: cut,
            levels: 1,
            coarsest_n: 4,
            blocks: vec![0, 1, 0, 1],
            // Exact binary fractions so the summed rendering is stable.
            phase_seconds: vec![
                ("coarsening", 0.25),
                ("initial", 0.125),
                ("uncoarsening", 0.5),
            ],
        };
        Aggregate::from_runs(vec![mk(2, 30), mk(1, 10)])
    }

    #[test]
    fn result_line_is_deterministic_json() {
        let agg = tiny_aggregate();
        let line = render_result_line("r\"1\"", &agg, false);
        assert!(line.starts_with("{\"id\":\"r\\\"1\\\"\",\"status\":\"ok\""), "{line}");
        assert!(line.contains("\"seeds\":[1,2]"), "{line}");
        assert!(line.contains("\"cuts\":[10,30]"), "{line}");
        assert!(line.contains("\"best_cut\":10"), "{line}");
        assert!(line.contains("\"avg_cut\":20"), "{line}");
        assert!(!line.contains("avg_seconds"), "{line}");
        assert!(!line.contains("phases"), "{line}");
        assert_eq!(line, render_result_line("r\"1\"", &agg, false));
        // timing is opt-in (and the only nondeterministic field set)
        let timed = render_result_line("x", &agg, true);
        assert!(timed.contains("avg_seconds"), "{timed}");
        // phases ride the timing gate: fixed names/order, summed across
        // the two runs (0.25+0.25, 0.125+0.125, 0.5+0.5)
        assert!(
            timed.contains(
                ",\"phases\":[{\"name\":\"coarsening\",\"seconds\":0.500000},\
                 {\"name\":\"initial\",\"seconds\":0.250000},\
                 {\"name\":\"uncoarsening\",\"seconds\":1.000000}]"
            ),
            "{timed}"
        );
    }

    #[test]
    fn workspace_stats_ride_the_timing_gate() {
        let agg = tiny_aggregate();
        // Without timing, lease stats never appear — the default line
        // stays byte-identical whether or not stats were supplied.
        let plain = render_result_line("x", &agg, false);
        assert_eq!(
            render_result_line_full("x", &agg, false, false, Some((7, 4096))),
            plain
        );
        // With timing they append after avg_seconds, in fixed order.
        let timed = render_result_line_full("x", &agg, true, false, Some((7, 4096)));
        assert!(
            timed.contains(",\"leases_created\":7,\"peak_lease_bytes\":4096"),
            "{timed}"
        );
        assert!(
            timed.find("avg_seconds").unwrap() < timed.find("leases_created").unwrap(),
            "{timed}"
        );
        // No stats supplied: the timing line is unchanged from before.
        assert_eq!(
            render_result_line_full("x", &agg, true, false, None),
            render_result_line("x", &agg, true)
        );
    }

    #[test]
    fn error_line_escapes() {
        let line = render_error_line("r1", "bad \"value\"\n");
        assert_eq!(
            line,
            "{\"id\":\"r1\",\"status\":\"error\",\"error\":\"bad \\\"value\\\"\\n\"}"
        );
    }

    #[test]
    fn timeout_and_race_parse_and_canonicalize() {
        let s = parse("graph=g k=4 timeout_ms=1500 race=CFast,UFast seeds=1,2");
        assert_eq!(s.timeout_ms, Some(1500));
        assert_eq!(s.race, vec![Preset::CFast, Preset::UFast]);
        // canonical order: race after preset, timeout_ms after seeds
        assert_eq!(
            s.to_line(),
            "id=d graph=g k=4 preset=CFast race=CFast,UFast seeds=1,2 timeout_ms=1500"
        );
        assert_eq!(parse(&s.to_line()), s);
        // racer configs are preset + shared options, in race order
        let s = parse("graph=g k=4 race=CFast,UFast epsilon=0.07");
        let racers = s.racer_configs().unwrap();
        assert_eq!(racers.len(), 2);
        assert_eq!(racers[0].0, "CFast");
        assert_eq!(racers[1].0, "UFast");
        for (_, c) in &racers {
            assert_eq!(c.k, 4);
            assert!((c.epsilon - 0.07).abs() < 1e-12);
        }
        // plain spec: no racers
        assert!(parse("graph=g k=2").racer_configs().unwrap().is_empty());
        // malformed values are loud
        assert!(parse_err("graph=g k=2 timeout_ms=0").contains("at least 1"));
        assert!(parse_err("graph=g k=2 timeout_ms=abc").contains("bad integer"));
        assert!(parse_err("graph=g k=2 race=CFast").contains("at least two"));
        assert!(parse_err("graph=g k=2 race=CFast,Bogus").contains("unknown preset"));
        assert!(parse_err("graph=g k=2 race=").contains("unknown preset"));
    }

    #[test]
    fn explain_parses_and_canonicalizes() {
        let s = parse("graph=g k=4 explain=true seeds=1,2");
        assert!(s.explain);
        // canonical order: explain after timeout_ms, before options
        assert_eq!(s.to_line(), "id=d graph=g k=4 preset=CFast seeds=1,2 explain=true");
        assert_eq!(parse(&s.to_line()), s);
        // explain=false is accepted and canonically omitted
        let s = parse("graph=g k=4 explain=false");
        assert!(!s.explain);
        assert_eq!(s.to_line(), "id=d graph=g k=4 preset=CFast seeds=1");
        // anything else is loud
        assert!(parse_err("graph=g k=4 explain=yes").contains("true/false"));
        assert!(parse_err("graph=g k=4 explain=").contains("true/false"));
    }

    #[test]
    fn explain_payload_renders_before_timing_fields() {
        let mut agg = tiny_aggregate();
        let plain = render_result_line("x", &agg, false);
        agg.explain = Some("{\"reps\":[]}".to_string());
        let explained = render_result_line("x", &agg, false);
        // the explain field is the ONLY difference, appended after the
        // deterministic prefix
        assert_eq!(
            explained,
            format!(
                "{},\"explain\":{{\"reps\":[]}}}}",
                &plain[..plain.len() - 1]
            )
        );
        // with timing, explain still precedes avg_seconds
        let timed = render_result_line("x", &agg, true);
        assert!(
            timed.find("\"explain\"").unwrap() < timed.find("avg_seconds").unwrap(),
            "{timed}"
        );
        // and the cached marker stays terminal
        let cached = render_result_line_cached("x", &agg, false, true);
        assert!(cached.ends_with(",\"cached\":true}"), "{cached}");
        assert!(cached.contains("\"explain\""), "{cached}");
    }

    #[test]
    fn cancelled_line_renders_reason() {
        use crate::util::cancel::CancelReason;
        assert_eq!(
            render_cancelled_line("r\"1\"", CancelReason::Timeout),
            "{\"id\":\"r\\\"1\\\"\",\"status\":\"cancelled\",\"reason\":\"timeout\"}"
        );
        assert_eq!(
            render_cancelled_line("x", CancelReason::Disconnect),
            "{\"id\":\"x\",\"status\":\"cancelled\",\"reason\":\"disconnect\"}"
        );
    }

    #[test]
    fn to_line_round_trips_and_is_canonical() {
        let line = "id=r1 graph=/tmp/g.graph k=8 preset=UFast seeds=3,1,2 \
                    epsilon=0.05 output=/tmp/o.txt";
        let spec = parse(line);
        assert_eq!(spec.to_line(), line);
        // reps/seed shorthand parses into the same canonical seeds= form
        let spec = parse("instance=tiny-rmat k=4 reps=3 seed=5");
        assert_eq!(spec.to_line(), "id=d instance=tiny-rmat k=4 preset=CFast seeds=5,6,7");
        // slash-named presets are emitted slash-free (token-clean)
        let spec = parse("shards=/tmp/dir k=2 preset=CFastVB");
        assert!(spec.to_line().contains("preset=CFastVB"), "{}", spec.to_line());
        assert_eq!(parse(&spec.to_line()), spec);
    }

    /// Random valid spec generator for the round-trip property.
    fn random_spec(rng: &mut crate::util::rng::Rng, size: usize) -> RequestSpec {
        let token = |rng: &mut crate::util::rng::Rng, prefix: &str| {
            let len = 1 + rng.below(6);
            let mut s = String::from(prefix);
            for _ in 0..len {
                let alphabet = b"abcdefghijklmnopqrstuvwxyz0123456789-_./";
                s.push(alphabet[rng.below(alphabet.len())] as char);
            }
            s
        };
        let source = match rng.below(3) {
            0 => RequestSource::GraphFile(token(rng, "/g/")),
            1 => RequestSource::Instance(token(rng, "i-")),
            _ => RequestSource::Shards(token(rng, "/s/")),
        };
        let preset = *rng.choose(&Preset::ALL);
        let seeds: Vec<u64> = (0..1 + rng.below(size.max(1)))
            .map(|_| rng.next_u64() % 1_000_000)
            .collect();
        let mut config_options = Vec::new();
        for key in CONFIG_OPTION_KEYS {
            if !rng.chance(0.4) {
                continue;
            }
            let value = match *key {
                "epsilon" => format!("0.{:02}", 1 + rng.below(98)),
                "lpa-iterations" => format!("{}", 1 + rng.below(20)),
                "threads" => format!("{}", rng.below(8)),
                "memory-budget" => format!("{}k", 1 + rng.below(100)),
                _ => (if rng.chance(0.5) { "true" } else { "false" }).to_string(),
            };
            config_options.push((key.to_string(), value));
        }
        rng.shuffle(&mut config_options);
        let race = if rng.chance(0.3) {
            (0..2 + rng.below(3)).map(|_| *rng.choose(&Preset::ALL)).collect()
        } else {
            Vec::new()
        };
        RequestSpec {
            id: token(rng, "r"),
            source,
            k: 1 + rng.below(64),
            preset,
            seeds,
            config_options,
            output: rng.chance(0.3).then(|| token(rng, "/o/")),
            timeout_ms: rng.chance(0.3).then(|| 1 + rng.next_u64() % 3_600_000),
            race,
            explain: rng.chance(0.3),
        }
    }

    #[test]
    fn property_format_parse_format_is_identity() {
        crate::util::proptest::for_random_cases(
            &crate::util::proptest::PropConfig::default(),
            |rng, size| {
                let spec = random_spec(rng, size);
                let line = spec.to_line();
                let parsed = parse_request_line(&line, "fallback")
                    .unwrap_or_else(|e| panic!("canonical line {line:?} rejected: {e}"))
                    .expect("canonical line is not blank");
                assert_eq!(parsed, spec, "round trip changed the spec for {line:?}");
                assert_eq!(parsed.to_line(), line);
                // and the config materializes (every generated option is valid)
                parsed.build_config().unwrap();
            },
        );
    }

    #[test]
    fn property_adversarial_lines_error_but_never_panic() {
        // Handwritten nasties first: huge numbers, NULs, truncations,
        // duplicates — each must produce Ok(None)/Ok(..)/Err(..), never
        // a panic, and the definite malformations must be errors.
        for line in [
            "k=99999999999999999999999999 graph=g",
            "graph=g k=2 seeds=99999999999999999999999999",
            "graph=g k=2 seed=-1",
            "graph=g\0withnul k=2",
            "graph=g k=2 epsilon=\0",
            "graph=",
            "k=",
            "=value",
            "graph=g k=2 preset=",
            "graph=g k=2 k=3",
            "id=a id=b graph=g k=2",
            "graph=g k=2 reps=0",
            "\u{7f}\u{1}=x",
            "graph=g k=2 timeout_ms=99999999999999999999999999",
            "graph=g k=2 timeout_ms=-5",
            "graph=g k=2 race=,,,",
            "graph=g k=2 race=CFast,CFast,CFast,CFast,CFast,CFast,CFast,CFast",
            "graph=g k=2 race=\0",
        ] {
            let _ = parse_request_line(line, "d");
        }
        assert!(parse_request_line("k=", "d").is_err());
        assert!(parse_request_line("graph=g k=99999999999999999999999999", "d").is_err());
        // Random garbage: arbitrary bytes from a hostile alphabet.
        crate::util::proptest::for_random_cases(
            &crate::util::proptest::PropConfig::default(),
            |rng, size| {
                let alphabet: Vec<char> =
                    "abk= ,.#!\t\0\u{1}\u{7f}=123-\\\"/émoji🦀".chars().collect();
                let line: String = (0..size * 4)
                    .map(|_| *rng.choose(&alphabet))
                    .collect();
                // must return, not panic; blank/comment lines are None
                match parse_request_line(&line, "d") {
                    Ok(Some(spec)) => {
                        // anything that parses must round-trip
                        assert_eq!(
                            parse_request_line(&spec.to_line(), "d").unwrap().unwrap(),
                            spec
                        );
                    }
                    Ok(None) => assert!(
                        line.trim().is_empty() || line.trim_start().starts_with('#')
                    ),
                    Err(e) => assert!(!e.is_empty()),
                }
            },
        );
    }

    #[test]
    fn busy_and_cached_renderings() {
        assert_eq!(
            render_busy_line("q\"7\""),
            "{\"id\":\"q\\\"7\\\"\",\"status\":\"busy\"}"
        );
        let agg = tiny_aggregate();
        let plain = render_result_line("x", &agg, false);
        let tagged = render_result_line_cached("x", &agg, false, true);
        // the cached marker is the ONLY difference — wire determinism
        // compares non-cached lines byte-for-byte with offline serve
        assert_eq!(
            tagged,
            format!("{},\"cached\":true}}", &plain[..plain.len() - 1])
        );
        assert_eq!(render_result_line_cached("x", &agg, false, false), plain);
    }

    #[test]
    fn fingerprint_distinguishes_partitions() {
        let a = blocks_fingerprint(&[0, 1, 0, 1]);
        let b = blocks_fingerprint(&[0, 1, 1, 0]);
        assert_ne!(a, b);
        assert_eq!(a, blocks_fingerprint(&[0, 1, 0, 1]));
        // FNV-1a of empty input is the offset basis
        assert_eq!(blocks_fingerprint(&[]), 0xcbf2_9ce4_8422_2325);
        // Known-answer vectors (reference FNV-1a 64 over the LE bytes),
        // so an external consumer can recompute the fingerprint.
        assert_eq!(blocks_fingerprint(&[1]), 0xad2a_ca77_4798_5764);
        assert_eq!(blocks_fingerprint(&[0, 1, 0, 1]), 0x32d7_4821_5c66_e845);
    }
}
