//! The batching scheduler: drains the request queue and fans
//! **individual repetitions** from many requests onto the one shared
//! pool in round-robin waves, reassembling results per request in seed
//! order. See the module docs in [`super`] for the model and the
//! determinism contract.

use super::{lock, GraphHandle, QueueShared, Reply, Request, RequestError};
use crate::coordinator::service::{run_repetition, Aggregate, RunOutcome};
use crate::graph::csr::Graph;
use crate::graph::store::{InMemoryStore, ShardedStore};
use crate::obs::metrics::MetricsRegistry;
use crate::partitioning::config::PartitionConfig;
use crate::partitioning::external::partition_store_with_ctx;
use crate::util::exec::ExecutionCtx;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Arc};

/// Where an activated request's topology lives. Cheap to clone into
/// per-repetition units (everything is behind an `Arc`).
#[derive(Clone)]
enum Backend {
    Mem(Arc<Graph>),
    Store(Arc<ShardedStore>),
}

/// One accepted request being scheduled: per-seed result slots plus the
/// dispatch cursor.
struct ActiveRequest {
    id: String,
    config: Arc<PartitionConfig>,
    seeds: Vec<u64>,
    /// `None` only when activation failed (then `failed` is set).
    backend: Option<Backend>,
    /// First seed index not yet dispatched (waves are synchronous, so
    /// dispatched implies completed by the time the next wave builds).
    next_seed: usize,
    results: Vec<Option<RunOutcome>>,
    reply: mpsc::Sender<Reply>,
    failed: Option<String>,
}

impl ActiveRequest {
    fn activate(req: Request, reply: mpsc::Sender<Reply>) -> ActiveRequest {
        let Request {
            id,
            graph,
            config,
            seeds,
        } = req;
        let mut failed = None;
        if seeds.is_empty() {
            failed = Some("request has no seeds".to_string());
        }
        let backend = match graph {
            GraphHandle::InMemory(g) => Some(Backend::Mem(g)),
            GraphHandle::Shards(dir) => match ShardedStore::open(&dir) {
                Ok(store) => Some(Backend::Store(Arc::new(store))),
                Err(e) => {
                    if failed.is_none() {
                        failed = Some(format!(
                            "opening shard directory {}: {e}",
                            dir.display()
                        ));
                    }
                    None
                }
            },
        };
        let slots = seeds.len();
        ActiveRequest {
            id,
            config: Arc::new(config),
            seeds,
            backend,
            next_seed: 0,
            results: vec![None; slots],
            reply,
            failed,
        }
    }
}

/// One repetition ready to execute: a pure function of its fields.
struct Unit {
    backend: Backend,
    config: Arc<PartitionConfig>,
    seed: u64,
}

/// The scheduler thread body: intake → wave → record → reap, until
/// shutdown has drained everything.
pub(super) fn scheduler_loop(shared: &Arc<QueueShared>, ctx: &Arc<ExecutionCtx>) {
    let metrics = ctx.metrics().clone();
    // Instrument handles resolved once; the loop updates them lock-free.
    let activated = metrics.counter("requests_activated");
    let waves = metrics.counter("scheduler_waves");
    let repetitions = metrics.counter("scheduler_repetitions");
    let wave_size = metrics.histogram("scheduler_wave_size");
    let depth = metrics.gauge("queue_depth");
    let mut active: Vec<ActiveRequest> = Vec::new();
    // Rotating fairness offset: each wave starts its round-robin one
    // request further along, so even a 1-wide wave (workers = 1) — or
    // more active requests than wave slots — serves every request
    // within `active.len()` waves instead of draining request 0 first.
    let mut rotate = 0usize;
    loop {
        // Intake: grab everything queued (unless paused); sleep only
        // when there is nothing to schedule at all.
        let newly: Vec<(Request, mpsc::Sender<Reply>)> = {
            let mut st = lock(&shared.state);
            loop {
                // Shutdown overrides pause so draining always finishes.
                let intake_allowed = !st.paused || st.shutting_down;
                if (intake_allowed && !st.pending.is_empty()) || !active.is_empty() {
                    break;
                }
                if st.shutting_down {
                    return; // queue empty, nothing active: fully drained
                }
                st = shared
                    .not_empty
                    .wait(st)
                    .unwrap_or_else(|p| p.into_inner());
            }
            if !st.paused || st.shutting_down {
                let drained: Vec<_> = st.pending.drain(..).collect();
                if !drained.is_empty() {
                    depth.set(st.pending.len() as i64);
                    shared.not_full.notify_all();
                }
                drained
            } else {
                Vec::new()
            }
        };
        activated.add(newly.len() as u64);
        for (req, reply) in newly {
            active.push(ActiveRequest::activate(req, reply));
        }
        // Activation failures (unopenable shard dir, no seeds) reply
        // immediately, before any wave is spent on them.
        reap(&mut active, &metrics);
        if active.is_empty() {
            continue;
        }

        // One wave of repetitions, interleaved across requests.
        let wave = build_wave(&active, ctx.threads().max(1), rotate % active.len());
        rotate = rotate.wrapping_add(1);
        waves.inc();
        repetitions.add(wave.len() as u64);
        wave_size.observe(wave.len() as u64);
        let units: Vec<Unit> = wave
            .iter()
            .map(|&(ri, si)| Unit {
                backend: active[ri]
                    .backend
                    .clone()
                    .expect("live request has a backend"),
                config: active[ri].config.clone(),
                seed: active[ri].seeds[si],
            })
            .collect();
        let results = run_wave(ctx, &units);
        for (&(ri, si), result) in wave.iter().zip(results) {
            let a = &mut active[ri];
            a.next_seed = a.next_seed.max(si + 1);
            match result {
                Ok(run) => a.results[si] = Some(run),
                // First failure wins (wave order is deterministic); the
                // request's remaining repetitions are not dispatched.
                Err(message) => {
                    if a.failed.is_none() {
                        a.failed = Some(message);
                    }
                }
            }
        }
        reap(&mut active, &metrics);
    }
}

/// Round-robin wave builder: one repetition per live request per cycle,
/// starting at request index `start` and wrapping, until the wave is
/// `target`-sized or nothing is left. With the caller's rotating
/// `start`, a 1-seed request rides a near-immediate wave instead of
/// queueing behind a bigger request's full seed list — even when the
/// wave is narrower than the active request count (e.g. workers = 1).
fn build_wave(active: &[ActiveRequest], target: usize, start: usize) -> Vec<(usize, usize)> {
    let mut wave = Vec::new();
    let mut cursor: Vec<usize> = active.iter().map(|a| a.next_seed).collect();
    loop {
        let mut took = false;
        for step in 0..active.len() {
            let ri = (start + step) % active.len();
            let a = &active[ri];
            if a.failed.is_some() {
                continue;
            }
            if cursor[ri] < a.seeds.len() {
                wave.push((ri, cursor[ri]));
                cursor[ri] += 1;
                took = true;
                if wave.len() >= target {
                    return wave;
                }
            }
        }
        if !took {
            return wave;
        }
    }
}

/// Execute one wave. Results come back in wave order; a repetition's
/// panic or I/O error becomes an `Err` for its own request only —
/// other requests' units in the same wave are unaffected.
fn run_wave(ctx: &Arc<ExecutionCtx>, units: &[Unit]) -> Vec<Result<RunOutcome, String>> {
    if units.len() == 1 {
        // Single unit: run on the scheduler thread so the repetition's
        // own parallel phases fan out across the pool instead of
        // nesting inline behind a one-task job (identical results by
        // thread-count invariance; better wall-clock).
        return vec![run_unit(ctx, &units[0])];
    }
    ctx.pool()
        .map_indexed(units.len(), |_worker, i| run_unit(ctx, &units[i]))
}

/// Execute one repetition; contains panics (a poisoned config must fail
/// its request, not the wave, the pool, or the service).
fn run_unit(ctx: &Arc<ExecutionCtx>, unit: &Unit) -> Result<RunOutcome, String> {
    let outcome = catch_unwind(AssertUnwindSafe(|| match &unit.backend {
        Backend::Mem(graph) => {
            if unit.config.memory_budget_bytes.is_some() {
                // Budgeted in-memory request: same store-backed path the
                // `partition` CLI takes, so the budget switch behaves
                // identically through the queue.
                let store = InMemoryStore::new(graph);
                return partition_store_with_ctx(&store, &unit.config, unit.seed, ctx)
                    .map(|r| RunOutcome::from_out_of_core(unit.seed, &r))
                    .map_err(|e| e.to_string());
            }
            Ok(run_repetition(ctx, graph, &unit.config, unit.seed))
        }
        Backend::Store(store) => {
            partition_store_with_ctx(store.as_ref(), &unit.config, unit.seed, ctx)
                .map(|r| RunOutcome::from_out_of_core(unit.seed, &r))
                .map_err(|e| e.to_string())
        }
    }));
    match outcome {
        Ok(result) => result,
        Err(payload) => Err(panic_message(&payload)),
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        format!("repetition panicked: {s}")
    } else if let Some(s) = payload.downcast_ref::<String>() {
        format!("repetition panicked: {s}")
    } else {
        "repetition panicked".to_string()
    }
}

/// Reply to and drop every finished request: failed ones with their
/// error, completed ones with an [`Aggregate`] over the seed-ordered
/// runs. A dropped ticket (client gone) is not an error.
fn reap(active: &mut Vec<ActiveRequest>, metrics: &MetricsRegistry) {
    active.retain_mut(|a| {
        if let Some(message) = a.failed.take() {
            metrics.counter("requests_failed").inc();
            let _ = a.reply.send(Err(RequestError {
                id: a.id.clone(),
                message,
            }));
            return false;
        }
        if a.results.iter().all(|r| r.is_some()) {
            let runs: Vec<RunOutcome> = a
                .results
                .drain(..)
                .map(|r| r.expect("all slots filled"))
                .collect();
            metrics.counter("requests_completed").inc();
            let _ = a.reply.send(Ok(Aggregate::from_runs(runs)));
            return false;
        }
        true
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy(seeds: usize, next: usize) -> ActiveRequest {
        // The receiver is dropped: these wave-shape tests never reply
        // (and `reap` tolerates a gone client anyway).
        let (tx, _rx) = mpsc::channel();
        ActiveRequest {
            id: "t".into(),
            config: Arc::new(crate::partitioning::config::PartitionConfig::preset(
                crate::partitioning::config::Preset::CFast,
                2,
            )),
            seeds: (1..=seeds as u64).collect(),
            backend: None,
            next_seed: next,
            results: vec![None; seeds],
            reply: tx,
            failed: None,
        }
    }

    #[test]
    fn wave_interleaves_round_robin() {
        // A(4 seeds), B(1), C(2) with a 5-wide wave: one repetition per
        // request per cycle — B's single seed rides the first cycle.
        let active = vec![dummy(4, 0), dummy(1, 0), dummy(2, 0)];
        let wave = build_wave(&active, 5, 0);
        assert_eq!(wave, vec![(0, 0), (1, 0), (2, 0), (0, 1), (2, 1)]);
    }

    #[test]
    fn wave_respects_cursor_and_target() {
        let active = vec![dummy(4, 2), dummy(3, 3)]; // B fully dispatched
        let wave = build_wave(&active, 8, 0);
        assert_eq!(wave, vec![(0, 2), (0, 3)]);
        let capped = build_wave(&active, 1, 0);
        assert_eq!(capped, vec![(0, 2)]);
    }

    #[test]
    fn wave_skips_failed_requests() {
        let mut active = vec![dummy(2, 0), dummy(2, 0)];
        active[0].failed = Some("boom".into());
        let wave = build_wave(&active, 4, 0);
        assert_eq!(wave, vec![(1, 0), (1, 1)]);
    }

    #[test]
    fn rotating_start_prevents_narrow_wave_starvation() {
        // workers = 1 ⇒ 1-wide waves. Without rotation every wave would
        // serve request 0 until it drained; with the scheduler's
        // rotating start, request 1 is served on the wave starting at
        // index 1.
        let active = vec![dummy(10, 0), dummy(1, 0)];
        assert_eq!(build_wave(&active, 1, 0), vec![(0, 0)]);
        assert_eq!(build_wave(&active, 1, 1), vec![(1, 0)]);
        // wrapping works, and a start past a drained request falls
        // through to the next live one
        let active = vec![dummy(2, 2), dummy(3, 0)]; // request 0 drained
        assert_eq!(build_wave(&active, 1, 0), vec![(1, 0)]);
        assert_eq!(build_wave(&active, 2, 1), vec![(1, 0), (1, 1)]);
    }

    #[test]
    fn panic_messages_extracted() {
        let err = catch_unwind(|| panic!("literal")).unwrap_err();
        assert_eq!(panic_message(&*err), "repetition panicked: literal");
        let err = catch_unwind(|| panic!("{}", String::from("formatted"))).unwrap_err();
        assert_eq!(panic_message(&*err), "repetition panicked: formatted");
    }
}
