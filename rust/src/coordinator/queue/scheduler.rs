//! The batching scheduler: drains the request queue and fans
//! **individual repetitions** from many requests onto the one shared
//! pool in round-robin waves, reassembling results per request in seed
//! order. See the module docs in [`super`] for the model and the
//! determinism contract.
//!
//! # Cancellation and races
//!
//! Each activated request's [`CancelToken`] is polled between waves
//! (and each dispatched unit carries a child token it enters
//! ambiently, so checkpoints inside the partitioning pipeline see it).
//! A fired token reaps the request with a cancelled reply instead of
//! completing it — queued repetitions are never dispatched, running
//! ones exit at their next checkpoint — and frees its queue slot and
//! arena leases like any other reap.
//!
//! A request with a non-empty `race` list first runs **every** racer
//! config on `seeds[0]` (the decision wave, interleaved like ordinary
//! repetitions). Once all racers have reported, the winner — lowest
//! cut, ties broken by race-list order, never by timing — keeps its
//! `seeds[0]` outcome and completes the remaining seeds; the losers'
//! remaining repetitions are cancelled (never dispatched — decisions
//! happen between synchronous waves, so no timing dependence exists).
//! The winning aggregate is byte-identical to running the winning
//! config alone.

use super::{lock, GraphHandle, QueueShared, Reply, Request, RequestError};
use crate::coordinator::service::{run_repetition, Aggregate, RunOutcome};
use crate::graph::csr::Graph;
use crate::graph::store::{InMemoryStore, ShardedStore};
use crate::obs::metrics::MetricsRegistry;
use crate::obs::quality::QualityReport;
use crate::obs::trace::{self, Tracer};
use crate::partitioning::config::PartitionConfig;
use crate::partitioning::external::partition_store_with_ctx;
use crate::util::cancel::{self, CancelReason, CancelToken, Cancelled};
use crate::util::exec::ExecutionCtx;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Arc};

/// Where an activated request's topology lives. Cheap to clone into
/// per-repetition units (everything is behind an `Arc`).
#[derive(Clone)]
enum Backend {
    Mem(Arc<Graph>),
    Store(Arc<ShardedStore>),
}

/// An undecided ensemble race: one result slot per racer config, all
/// evaluated on the request's first seed.
struct RaceState {
    /// `(name, config)` in race-list order — the deterministic
    /// tie-break order.
    entries: Vec<(String, Arc<PartitionConfig>)>,
    /// Racer outcomes on `seeds[0]`, indexed like `entries`.
    first_results: Vec<Option<RunOutcome>>,
    /// First racer index not yet dispatched (synchronous waves:
    /// dispatched implies completed by the next wave build).
    next_racer: usize,
    /// Set by [`decide_races`]; afterwards the request schedules like a
    /// plain one under the winning config.
    winner: Option<usize>,
}

/// One accepted request being scheduled: per-seed result slots plus the
/// dispatch cursor.
struct ActiveRequest {
    id: String,
    config: Arc<PartitionConfig>,
    seeds: Vec<u64>,
    /// `None` only when activation failed (then `failed` is set).
    backend: Option<Backend>,
    /// First seed index not yet dispatched (waves are synchronous, so
    /// dispatched implies completed by the time the next wave builds).
    next_seed: usize,
    results: Vec<Option<RunOutcome>>,
    reply: mpsc::Sender<Reply>,
    failed: Option<String>,
    /// Request-root cancellation token; units run under child tokens.
    cancel: CancelToken,
    /// `Some` while an ensemble race is undecided (or decided — see
    /// [`RaceState::winner`]); `None` for plain requests.
    race: Option<RaceState>,
    /// A fired token reaps the request with this reason.
    cancelled: Option<CancelReason>,
    /// Per-request explain tracer ([`Request::explain`]): every unit of
    /// this request runs under a deterministic lane of this tracer, and
    /// the reap renders the collected events into
    /// [`Aggregate::explain`]. `None` when explain was not requested.
    explain: Option<Arc<Tracer>>,
}

impl ActiveRequest {
    fn activate(req: Request, reply: mpsc::Sender<Reply>) -> ActiveRequest {
        let Request {
            id,
            graph,
            config,
            seeds,
            timeout_ms: _, // armed on the token at submission
            race,
            cancel,
            explain,
        } = req;
        let mut failed = None;
        if seeds.is_empty() {
            failed = Some("request has no seeds".to_string());
        }
        let backend = match graph {
            GraphHandle::InMemory(g) => Some(Backend::Mem(g)),
            GraphHandle::Shards(dir) => match ShardedStore::open(&dir) {
                Ok(store) => Some(Backend::Store(Arc::new(store))),
                Err(e) => {
                    if failed.is_none() {
                        failed = Some(format!(
                            "opening shard directory {}: {e}",
                            dir.display()
                        ));
                    }
                    None
                }
            },
        };
        let race = if race.is_empty() {
            None
        } else {
            let entries: Vec<(String, Arc<PartitionConfig>)> = race
                .into_iter()
                .map(|e| (e.name, Arc::new(e.config)))
                .collect();
            let slots = entries.len();
            Some(RaceState {
                entries,
                first_results: vec![None; slots],
                next_racer: 0,
                winner: None,
            })
        };
        let slots = seeds.len();
        ActiveRequest {
            id,
            config: Arc::new(config),
            seeds,
            backend,
            next_seed: 0,
            results: vec![None; slots],
            reply,
            failed,
            cancel,
            race,
            cancelled: None,
            explain: explain.then(|| Arc::new(Tracer::new())),
        }
    }

    /// Whether this request still races (racers pending, no winner).
    fn race_undecided(&self) -> bool {
        matches!(&self.race, Some(r) if r.winner.is_none())
    }

    /// Dispatch cursor: racer index while the race is undecided, seed
    /// index otherwise.
    fn cursor(&self) -> usize {
        match &self.race {
            Some(r) if r.winner.is_none() => r.next_racer,
            _ => self.next_seed,
        }
    }

    /// Number of dispatchable units in the current mode (racers while
    /// undecided, seeds otherwise).
    fn unit_count(&self) -> usize {
        match &self.race {
            Some(r) if r.winner.is_none() => r.entries.len(),
            _ => self.seeds.len(),
        }
    }

    /// Whether the wave builder may dispatch units for this request.
    fn schedulable(&self) -> bool {
        self.failed.is_none() && self.cancelled.is_none()
    }
}

/// One repetition ready to execute: a pure function of `backend` ×
/// `config` × `seed` (the token only decides *whether* it runs to
/// completion, never what it computes).
struct Unit {
    backend: Backend,
    config: Arc<PartitionConfig>,
    seed: u64,
    /// Child of the owning request's token, entered ambiently for the
    /// duration of the unit.
    cancel: CancelToken,
    /// The owning request's explain tracer, if any: the unit runs under
    /// lane `(Tracer::track_of(seed), lane)` of it, so trace events
    /// land in a slot that depends only on the request — never on
    /// worker count or wave interleaving.
    explain: Option<Arc<Tracer>>,
    /// Deterministic lane coordinate: the racer index while the race is
    /// undecided, `race.entries.len() + seed index` after a decision
    /// (offset so a seed equal to `seeds[0]` cannot collide with a
    /// racer lane on the same track), plain seed index otherwise.
    lane: u32,
}

/// What became of one dispatched unit.
enum UnitOutcome {
    Done(RunOutcome),
    Failed(String),
    Cancelled(CancelReason),
}

/// The scheduler thread body: intake → wave → record → reap, until
/// shutdown has drained everything.
pub(super) fn scheduler_loop(shared: &Arc<QueueShared>, ctx: &Arc<ExecutionCtx>) {
    let metrics = ctx.metrics().clone();
    // Instrument handles resolved once; the loop updates them lock-free.
    let activated = metrics.counter("requests_activated");
    let waves = metrics.counter("scheduler_waves");
    let repetitions = metrics.counter("scheduler_repetitions");
    let wave_size = metrics.histogram("scheduler_wave_size");
    let depth = metrics.gauge("queue_depth");
    let mut active: Vec<ActiveRequest> = Vec::new();
    // Rotating fairness offset: each wave starts its round-robin one
    // request further along, so even a 1-wide wave (workers = 1) — or
    // more active requests than wave slots — serves every request
    // within `active.len()` waves instead of draining request 0 first.
    let mut rotate = 0usize;
    loop {
        // Intake: grab everything queued (unless paused); sleep only
        // when there is nothing to schedule at all.
        let newly: Vec<(Request, mpsc::Sender<Reply>)> = {
            let mut st = lock(&shared.state);
            loop {
                // Shutdown overrides pause so draining always finishes.
                let intake_allowed = !st.paused || st.shutting_down;
                if (intake_allowed && !st.pending.is_empty()) || !active.is_empty() {
                    break;
                }
                if st.shutting_down {
                    return; // queue empty, nothing active: fully drained
                }
                st = shared
                    .not_empty
                    .wait(st)
                    .unwrap_or_else(|p| p.into_inner());
            }
            if !st.paused || st.shutting_down {
                let drained: Vec<_> = st.pending.drain(..).collect();
                if !drained.is_empty() {
                    depth.set(st.pending.len() as i64);
                    shared.not_full.notify_all();
                }
                drained
            } else {
                Vec::new()
            }
        };
        activated.add(newly.len() as u64);
        for (req, reply) in newly {
            if let Some(hook) = &shared.on_event {
                hook("started", &req.id);
            }
            active.push(ActiveRequest::activate(req, reply));
        }
        // Cancellations (abandoned tickets, deadlines that expired in
        // the queue) and activation failures (unopenable shard dir, no
        // seeds) reply immediately, before any wave is spent on them.
        poll_cancellations(&mut active);
        reap(&mut active, &metrics);
        if active.is_empty() {
            continue;
        }

        // One wave of repetitions, interleaved across requests. While
        // a request's race is undecided, its units are racer configs
        // on its first seed instead of seeds under its own config.
        let wave = build_wave(&active, ctx.threads().max(1), rotate % active.len());
        rotate = rotate.wrapping_add(1);
        waves.inc();
        repetitions.add(wave.len() as u64);
        wave_size.observe(wave.len() as u64);
        let units: Vec<Unit> = wave
            .iter()
            .map(|&(ri, ui)| {
                let a = &active[ri];
                let (config, seed, lane) = if a.race_undecided() {
                    let race = a.race.as_ref().expect("undecided race present");
                    (race.entries[ui].1.clone(), a.seeds[0], ui)
                } else {
                    // Post-decision seed lanes are offset past the racer
                    // lanes (see `Unit::lane`).
                    let offset = a.race.as_ref().map_or(0, |r| r.entries.len());
                    (a.config.clone(), a.seeds[ui], offset + ui)
                };
                Unit {
                    backend: a.backend.clone().expect("live request has a backend"),
                    config,
                    seed,
                    cancel: a.cancel.child(),
                    explain: a.explain.clone(),
                    lane: lane as u32,
                }
            })
            .collect();
        let results = run_wave(ctx, &units);
        for (&(ri, ui), outcome) in wave.iter().zip(results) {
            let a = &mut active[ri];
            if a.race_undecided() {
                {
                    let race = a.race.as_mut().expect("undecided race present");
                    race.next_racer = race.next_racer.max(ui + 1);
                }
                match outcome {
                    UnitOutcome::Done(run) => {
                        a.race.as_mut().expect("undecided race present").first_results[ui] =
                            Some(run);
                    }
                    // A failing or cancelled racer takes the whole
                    // request with it — first cause wins (wave order
                    // is deterministic).
                    UnitOutcome::Failed(message) => {
                        if a.failed.is_none() {
                            a.failed = Some(message);
                        }
                    }
                    UnitOutcome::Cancelled(reason) => {
                        if a.cancelled.is_none() {
                            a.cancelled = Some(reason);
                        }
                    }
                }
            } else {
                a.next_seed = a.next_seed.max(ui + 1);
                match outcome {
                    UnitOutcome::Done(run) => a.results[ui] = Some(run),
                    // First failure wins (wave order is deterministic);
                    // the request's remaining repetitions are not
                    // dispatched.
                    UnitOutcome::Failed(message) => {
                        if a.failed.is_none() {
                            a.failed = Some(message);
                        }
                    }
                    UnitOutcome::Cancelled(reason) => {
                        if a.cancelled.is_none() {
                            a.cancelled = Some(reason);
                        }
                    }
                }
            }
        }
        // Race decisions happen here — strictly between synchronous
        // waves — so the winner never depends on unit timing.
        decide_races(&mut active, &metrics);
        poll_cancellations(&mut active);
        reap(&mut active, &metrics);
    }
}

/// Mark requests whose token has fired (deadline passed, ticket
/// dropped, client disconnected, explicit fire) as cancelled so the
/// next reap replies and the wave builder skips them. Never overrides
/// an earlier failure or cancellation.
fn poll_cancellations(active: &mut [ActiveRequest]) {
    for a in active.iter_mut() {
        if a.failed.is_none() && a.cancelled.is_none() {
            if let Some(reason) = a.cancel.poll() {
                a.cancelled = Some(reason);
            }
        }
    }
}

/// Resolve every race whose racers have all reported: lowest cut wins,
/// ties break on race-list order (never timing). The winner's
/// first-seed outcome becomes the request's `results[0]` and its
/// config replaces the request config for the remaining seeds; the
/// losers' remaining repetitions are cancelled by never being
/// dispatched.
fn decide_races(active: &mut [ActiveRequest], metrics: &MetricsRegistry) {
    for a in active.iter_mut() {
        if a.failed.is_some() || a.cancelled.is_some() {
            continue;
        }
        let Some(race) = &mut a.race else { continue };
        if race.winner.is_some() || !race.first_results.iter().all(|r| r.is_some()) {
            continue;
        }
        let mut win = 0usize;
        for i in 1..race.first_results.len() {
            let best = race.first_results[win].as_ref().expect("all reported").cut;
            let cand = race.first_results[i].as_ref().expect("all reported").cut;
            if cand < best {
                win = i;
            }
        }
        race.winner = Some(win);
        let losers = race.entries.len().saturating_sub(1);
        metrics.counter("race_losers_cancelled").add(losers as u64);
        trace::counter(
            "race_decided",
            &[("winner", win as i64), ("losers", losers as i64)],
        );
        a.config = race.entries[win].1.clone();
        a.results[0] = race.first_results[win].take();
        a.next_seed = 1;
    }
}

/// Round-robin wave builder: one unit per live request per cycle,
/// starting at request index `start` and wrapping, until the wave is
/// `target`-sized or nothing is left. With the caller's rotating
/// `start`, a 1-seed request rides a near-immediate wave instead of
/// queueing behind a bigger request's full seed list — even when the
/// wave is narrower than the active request count (e.g. workers = 1).
///
/// Each pair is `(request index, unit index)`; the unit index is a
/// **racer** index while the request's race is undecided and a **seed**
/// index otherwise (the mode cannot change inside a wave — decisions
/// happen strictly between waves).
fn build_wave(active: &[ActiveRequest], target: usize, start: usize) -> Vec<(usize, usize)> {
    let mut wave = Vec::new();
    let mut cursor: Vec<usize> = active.iter().map(|a| a.cursor()).collect();
    loop {
        let mut took = false;
        for step in 0..active.len() {
            let ri = (start + step) % active.len();
            let a = &active[ri];
            if !a.schedulable() {
                continue;
            }
            if cursor[ri] < a.unit_count() {
                wave.push((ri, cursor[ri]));
                cursor[ri] += 1;
                took = true;
                if wave.len() >= target {
                    return wave;
                }
            }
        }
        if !took {
            return wave;
        }
    }
}

/// Execute one wave. Results come back in wave order; a repetition's
/// panic, I/O error, or cancellation becomes an outcome for its own
/// request only — other requests' units in the same wave are
/// unaffected.
fn run_wave(ctx: &Arc<ExecutionCtx>, units: &[Unit]) -> Vec<UnitOutcome> {
    if units.len() == 1 {
        // Single unit: run on the scheduler thread so the repetition's
        // own parallel phases fan out across the pool instead of
        // nesting inline behind a one-task job (identical results by
        // thread-count invariance; better wall-clock).
        return vec![run_unit(ctx, &units[0])];
    }
    ctx.pool()
        .map_indexed(units.len(), |_worker, i| run_unit(ctx, &units[i]))
}

/// Execute one repetition under its cancel token; contains panics (a
/// poisoned config must fail its request, not the wave, the pool, or
/// the service) and downcasts the typed [`Cancelled`] payload so
/// cancellation is an outcome, not an error.
fn run_unit(ctx: &Arc<ExecutionCtx>, unit: &Unit) -> UnitOutcome {
    // A unit whose token fired before it started never computes.
    if let Some(reason) = unit.cancel.poll() {
        return UnitOutcome::Cancelled(reason);
    }
    // Explain lane, ambient for the whole repetition: the pipeline's
    // own `ctx.tracer().enter(seed)` finds the slot occupied and stays
    // inert, so its spans and counters flow into this request's
    // tracer at a (track, lane) coordinate that is a pure function of
    // the request — worker-count-invariant by construction. (While a
    // request carries both `--trace` and `explain=true`, the shared
    // trace file loses that request's spans to the explain report.)
    let _lane = unit
        .explain
        .as_ref()
        .map(|t| t.enter_lane(Tracer::track_of(unit.seed), unit.lane));
    // Ambient for the whole repetition: every checkpoint inside the
    // pipeline (and every pool job the repetition dispatches) sees
    // this unit's token.
    let _scope = cancel::enter(unit.cancel.clone());
    let outcome = catch_unwind(AssertUnwindSafe(|| match &unit.backend {
        Backend::Mem(graph) => {
            if unit.config.memory_budget_bytes.is_some() {
                // Budgeted in-memory request: same store-backed path the
                // `partition` CLI takes, so the budget switch behaves
                // identically through the queue.
                let store = InMemoryStore::new(graph);
                return partition_store_with_ctx(&store, &unit.config, unit.seed, ctx)
                    .map(|r| RunOutcome::from_out_of_core(unit.seed, &r))
                    .map_err(|e| e.to_string());
            }
            Ok(run_repetition(ctx, graph, &unit.config, unit.seed))
        }
        Backend::Store(store) => {
            partition_store_with_ctx(store.as_ref(), &unit.config, unit.seed, ctx)
                .map(|r| RunOutcome::from_out_of_core(unit.seed, &r))
                .map_err(|e| e.to_string())
        }
    }));
    match outcome {
        Ok(Ok(run)) => UnitOutcome::Done(run),
        Ok(Err(message)) => UnitOutcome::Failed(message),
        Err(payload) => match payload.downcast_ref::<Cancelled>() {
            Some(c) => UnitOutcome::Cancelled(c.reason),
            None => UnitOutcome::Failed(panic_message(&payload)),
        },
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        format!("repetition panicked: {s}")
    } else if let Some(s) = payload.downcast_ref::<String>() {
        format!("repetition panicked: {s}")
    } else {
        "repetition panicked".to_string()
    }
}

/// Reply to and drop every finished request: failed ones with their
/// error, cancelled ones with a cancelled [`RequestError`], completed
/// ones with an [`Aggregate`] over the seed-ordered runs. A dropped
/// ticket (client gone) is not an error.
fn reap(active: &mut Vec<ActiveRequest>, metrics: &MetricsRegistry) {
    active.retain_mut(|a| {
        if let Some(message) = a.failed.take() {
            metrics.counter("requests_failed").inc();
            let _ = a
                .reply
                .send(Err(RequestError::new(a.id.clone(), message)));
            return false;
        }
        if let Some(reason) = a.cancelled.take() {
            metrics.counter("requests_cancelled").inc();
            metrics.counter(reason.counter_name()).inc();
            trace::counter("request_cancelled", &[("reason", reason.code() as i64)]);
            let _ = a
                .reply
                .send(Err(RequestError::cancelled_with(a.id.clone(), reason)));
            return false;
        }
        if a.race_undecided() {
            // Racers still pending: the per-seed slots cannot be
            // complete yet (decision fills `results[0]`).
            return true;
        }
        if a.results.iter().all(|r| r.is_some()) {
            let runs: Vec<RunOutcome> = a
                .results
                .drain(..)
                .map(|r| r.expect("all slots filled"))
                .collect();
            metrics.counter("requests_completed").inc();
            let mut agg = Aggregate::from_runs(runs);
            if let Some(tracer) = a.explain.take() {
                metrics.counter("requests_explained").inc();
                let lanes = explain_lanes(&a.seeds, a.race.as_ref());
                agg.explain = Some(QualityReport::from_lanes(&tracer, &lanes).to_json());
            }
            let _ = a.reply.send(Ok(agg));
            return false;
        }
        true
    });
}

/// The aggregate-contributing `(seed, lane)` coordinates of a completed
/// request, mirroring the wave builder's lane assignment: plain
/// requests contribute `(seeds[i], i)`; raced requests contribute the
/// winning racer's lane for `seeds[0]` plus the offset seed lanes for
/// the rest. Losers' lanes stay in the tracer but are not reported —
/// the explain payload describes exactly the runs in the aggregate.
fn explain_lanes(seeds: &[u64], race: Option<&RaceState>) -> Vec<(u64, u32)> {
    match race {
        Some(race) => {
            let win = race.winner.expect("reaped race is decided") as u32;
            let offset = race.entries.len();
            std::iter::once((seeds[0], win))
                .chain(
                    seeds
                        .iter()
                        .enumerate()
                        .skip(1)
                        .map(|(i, &s)| (s, (offset + i) as u32)),
                )
                .collect()
        }
        None => seeds.iter().enumerate().map(|(i, &s)| (s, i as u32)).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfast() -> Arc<PartitionConfig> {
        Arc::new(crate::partitioning::config::PartitionConfig::preset(
            crate::partitioning::config::Preset::CFast,
            2,
        ))
    }

    fn dummy(seeds: usize, next: usize) -> ActiveRequest {
        // The receiver is dropped: these wave-shape tests never reply
        // (and `reap` tolerates a gone client anyway).
        let (tx, _rx) = mpsc::channel();
        ActiveRequest {
            id: "t".into(),
            config: cfast(),
            seeds: (1..=seeds as u64).collect(),
            backend: None,
            next_seed: next,
            results: vec![None; seeds],
            reply: tx,
            failed: None,
            cancel: CancelToken::new(),
            race: None,
            cancelled: None,
            explain: None,
        }
    }

    fn racing(seeds: usize, racers: usize) -> ActiveRequest {
        let mut a = dummy(seeds, 0);
        a.race = Some(RaceState {
            entries: (0..racers)
                .map(|i| (format!("cfg{i}"), cfast()))
                .collect(),
            first_results: vec![None; racers],
            next_racer: 0,
            winner: None,
        });
        a
    }

    fn run_with_cut(seed: u64, cut: crate::graph::csr::Weight) -> RunOutcome {
        RunOutcome {
            seed,
            cut,
            seconds: 0.0,
            imbalance: 0.0,
            feasible: true,
            initial_cut: 0,
            levels: 1,
            coarsest_n: 1,
            blocks: vec![0, 1],
            phase_seconds: Vec::new(),
        }
    }

    #[test]
    fn wave_interleaves_round_robin() {
        // A(4 seeds), B(1), C(2) with a 5-wide wave: one repetition per
        // request per cycle — B's single seed rides the first cycle.
        let active = vec![dummy(4, 0), dummy(1, 0), dummy(2, 0)];
        let wave = build_wave(&active, 5, 0);
        assert_eq!(wave, vec![(0, 0), (1, 0), (2, 0), (0, 1), (2, 1)]);
    }

    #[test]
    fn wave_respects_cursor_and_target() {
        let active = vec![dummy(4, 2), dummy(3, 3)]; // B fully dispatched
        let wave = build_wave(&active, 8, 0);
        assert_eq!(wave, vec![(0, 2), (0, 3)]);
        let capped = build_wave(&active, 1, 0);
        assert_eq!(capped, vec![(0, 2)]);
    }

    #[test]
    fn wave_skips_failed_requests() {
        let mut active = vec![dummy(2, 0), dummy(2, 0)];
        active[0].failed = Some("boom".into());
        let wave = build_wave(&active, 4, 0);
        assert_eq!(wave, vec![(1, 0), (1, 1)]);
    }

    #[test]
    fn rotating_start_prevents_narrow_wave_starvation() {
        // workers = 1 ⇒ 1-wide waves. Without rotation every wave would
        // serve request 0 until it drained; with the scheduler's
        // rotating start, request 1 is served on the wave starting at
        // index 1.
        let active = vec![dummy(10, 0), dummy(1, 0)];
        assert_eq!(build_wave(&active, 1, 0), vec![(0, 0)]);
        assert_eq!(build_wave(&active, 1, 1), vec![(1, 0)]);
        // wrapping works, and a start past a drained request falls
        // through to the next live one
        let active = vec![dummy(2, 2), dummy(3, 0)]; // request 0 drained
        assert_eq!(build_wave(&active, 1, 0), vec![(1, 0)]);
        assert_eq!(build_wave(&active, 2, 1), vec![(1, 0), (1, 1)]);
    }

    #[test]
    fn undecided_race_dispatches_racers_not_seeds() {
        // 3 racers × seeds[0] before any ordinary seed unit; a plain
        // request interleaves as usual.
        let active = vec![racing(5, 3), dummy(2, 0)];
        let wave = build_wave(&active, 8, 0);
        assert_eq!(wave, vec![(0, 0), (1, 0), (0, 1), (1, 1), (0, 2)]);
    }

    #[test]
    fn decided_race_schedules_remaining_seeds_under_the_winner() {
        let mut active = vec![racing(3, 2)];
        {
            let race = active[0].race.as_mut().unwrap();
            race.next_racer = 2;
            race.first_results = vec![Some(run_with_cut(1, 10)), Some(run_with_cut(1, 7))];
        }
        let metrics = MetricsRegistry::new();
        decide_races(&mut active, &metrics);
        let a = &active[0];
        assert_eq!(a.race.as_ref().unwrap().winner, Some(1));
        assert_eq!(a.results[0].as_ref().unwrap().cut, 7);
        assert_eq!(a.next_seed, 1);
        assert!(!a.race_undecided());
        // Remaining units are now ordinary seed indices 1..3.
        assert_eq!(build_wave(&active, 8, 0), vec![(0, 1), (0, 2)]);
        assert_eq!(metrics.counter("race_losers_cancelled").get(), 1);
    }

    #[test]
    fn race_ties_break_on_race_list_order() {
        let mut active = vec![racing(1, 3)];
        {
            let race = active[0].race.as_mut().unwrap();
            race.next_racer = 3;
            race.first_results = vec![
                Some(run_with_cut(1, 9)),
                Some(run_with_cut(1, 5)),
                Some(run_with_cut(1, 5)), // same cut, later in the list
            ];
        }
        let metrics = MetricsRegistry::new();
        decide_races(&mut active, &metrics);
        assert_eq!(active[0].race.as_ref().unwrap().winner, Some(1));
    }

    #[test]
    fn cancelled_requests_get_no_wave_units() {
        let mut active = vec![dummy(4, 0), dummy(4, 0)];
        active[0].cancelled = Some(CancelReason::Timeout);
        assert_eq!(build_wave(&active, 8, 0), vec![(1, 0), (1, 1), (1, 2), (1, 3)]);
    }

    #[test]
    fn fired_token_is_observed_and_reaped_as_cancelled() {
        let (tx, rx) = mpsc::channel();
        let mut a = dummy(2, 0);
        a.reply = tx;
        a.cancel.fire(CancelReason::Abandoned);
        let mut active = vec![a];
        poll_cancellations(&mut active);
        assert_eq!(active[0].cancelled, Some(CancelReason::Abandoned));
        let metrics = MetricsRegistry::new();
        reap(&mut active, &metrics);
        assert!(active.is_empty());
        let err = rx.recv().expect("cancelled reply sent").unwrap_err();
        assert_eq!(err.cancelled, Some(CancelReason::Abandoned));
        assert_eq!(metrics.counter("requests_cancelled").get(), 1);
        assert_eq!(metrics.counter("cancel_reason_abandoned").get(), 1);
    }

    #[test]
    fn explain_lanes_mirror_dispatch_lanes() {
        // Plain request: lane = seed index, duplicates included.
        assert_eq!(
            explain_lanes(&[7, 7, 9], None),
            vec![(7, 0), (7, 1), (9, 2)]
        );
        // Raced request: seeds[0] reports under the winning racer's
        // lane; later seeds are offset past the racer lanes — so a
        // seed equal to seeds[0] (here 5 again at index 1) lands on
        // lane 3+1=4, never colliding with racer lanes 0..3.
        let mut a = racing(3, 3);
        a.seeds = vec![5, 5, 6];
        a.race.as_mut().unwrap().winner = Some(2);
        assert_eq!(
            explain_lanes(&a.seeds, a.race.as_ref()),
            vec![(5, 2), (5, 4), (6, 5)]
        );
        // ...and `from_lanes` orders by (seed, lane), matching the
        // seed-sorted aggregate for distinct seeds.
    }

    #[test]
    fn panic_messages_extracted() {
        let err = catch_unwind(|| panic!("literal")).unwrap_err();
        assert_eq!(panic_message(&*err), "repetition panicked: literal");
        let err = catch_unwind(|| panic!("{}", String::from("formatted"))).unwrap_err();
        assert_eq!(panic_message(&*err), "repetition panicked: formatted");
    }
}
