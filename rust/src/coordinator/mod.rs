//! L3 coordinator: the partitioning service (worker pool, repetition
//! batching, aggregation — the paper's §5 protocol), the batching
//! service front end ([`queue`]: bounded multi-producer request queue,
//! repetition-interleaved scheduling, backpressure, graceful shutdown),
//! the network service layer ([`net`]: TCP server/client over the
//! queue with a content-addressed partition cache), and the CLI front
//! end.

pub mod cli;
pub mod net;
pub mod queue;
pub mod service;

pub use cli::Args;
pub use net::{CachedService, NetClient, NetServer, NetServerConfig};
pub use queue::{
    BatchService, GraphHandle, Request, RequestError, ServiceConfig, SubmitError, Ticket,
};
pub use service::{default_seeds, Aggregate, Coordinator, RunOutcome};
