//! L3 coordinator: the partitioning service (worker pool, repetition
//! batching, aggregation — the paper's §5 protocol) and the CLI front end.

pub mod cli;
pub mod service;

pub use cli::Args;
pub use service::{default_seeds, Aggregate, Coordinator, RunOutcome};
