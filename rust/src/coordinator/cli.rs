//! Minimal CLI argument parsing (the `clap` crate is not available
//! offline — DESIGN.md §3). Flags are `--key value` or `--flag`.

use std::collections::HashMap;

/// Parsed command line: a subcommand plus `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub options: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut iter = args.into_iter().peekable();
        let command = iter.next().unwrap_or_default();
        let mut options = HashMap::new();
        let mut positional = Vec::new();
        while let Some(arg) = iter.next() {
            if let Some(key) = arg.strip_prefix("--") {
                let value = match iter.peek() {
                    Some(next) if !next.starts_with("--") => iter.next().unwrap(),
                    _ => "true".to_string(), // boolean flag
                };
                if options.insert(key.to_string(), value).is_some() {
                    return Err(format!("duplicate option --{key}"));
                }
            } else {
                positional.push(arg);
            }
        }
        Ok(Args {
            command,
            options,
            positional,
        })
    }

    pub fn parse_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad integer {v:?}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad integer {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad float {v:?}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = parse("partition --k 8 --preset UFast --graph g.bin");
        assert_eq!(a.command, "partition");
        assert_eq!(a.get("k"), Some("8"));
        assert_eq!(a.get("preset"), Some("UFast"));
        assert_eq!(a.get_usize("k", 2).unwrap(), 8);
    }

    #[test]
    fn boolean_flags() {
        let a = parse("bench --quick --reps 3");
        assert!(a.flag("quick"));
        assert!(!a.flag("verbose"));
        assert_eq!(a.get_usize("reps", 10).unwrap(), 3);
    }

    #[test]
    fn defaults() {
        let a = parse("stats");
        assert_eq!(a.get_or("graph", "none"), "none");
        assert_eq!(a.get_f64("epsilon", 0.03).unwrap(), 0.03);
        assert_eq!(a.get_u64("seed", 1).unwrap(), 1);
    }

    #[test]
    fn positional_args() {
        let a = parse("stats file1.graph file2.graph --quick");
        assert_eq!(a.positional, vec!["file1.graph", "file2.graph"]);
    }

    #[test]
    fn duplicate_option_rejected() {
        assert!(Args::parse(
            "x --k 1 --k 2".split_whitespace().map(String::from)
        )
        .is_err());
    }

    #[test]
    fn bad_number_reported() {
        let a = parse("x --k eight");
        assert!(a.get_usize("k", 2).is_err());
    }
}
