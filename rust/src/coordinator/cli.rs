//! Minimal CLI argument parsing (the `clap` crate is not available
//! offline — DESIGN.md §3).
//!
//! Parsing is **spec-driven**: every subcommand declares which option
//! keys take a value and which are boolean flags ([`CommandSpec`],
//! [`COMMANDS`]). This closes two silent-failure holes the old
//! permissive parser had:
//!
//! - a boolean flag followed by a positional argument no longer
//!   swallows the positional as its "value"
//!   (`partition --parallel-coarsening g.graph` keeps `g.graph`);
//! - an unrecognized option is an error with a did-you-mean suggestion
//!   (`--memory-bugdet 1g` fails loudly instead of running fully
//!   in-memory with no warning).
//!
//! Accepted forms: `--key value`, `--key=value`, `--flag`,
//! `--flag=true|false`, and a literal `--` that turns every remaining
//! token into a positional.

use std::collections::HashMap;

/// Option schema of one subcommand: which `--keys` take a value and
/// which are boolean flags. Anything else is rejected at parse time.
#[derive(Debug, Clone, Copy)]
pub struct CommandSpec {
    pub name: &'static str,
    pub value_keys: &'static [&'static str],
    pub flag_keys: &'static [&'static str],
}

/// The full subcommand table (kept in sync with `main.rs::run` — see
/// the unit tests).
pub const COMMANDS: &[CommandSpec] = &[
    CommandSpec {
        name: "partition",
        value_keys: &[
            "graph",
            "instance",
            "shards",
            "k",
            "preset",
            "epsilon",
            "lpa-iterations",
            "threads",
            "reps",
            "seed",
            "workers",
            "memory-budget",
            "output",
            "trace",
        ],
        flag_keys: &["parallel-coarsening", "parallel-refinement"],
    },
    CommandSpec {
        name: "serve",
        value_keys: &[
            "requests",
            "workers",
            "max-pending",
            "listen",
            "cache",
            "trace",
            "journal",
        ],
        flag_keys: &["timing"],
    },
    CommandSpec {
        name: "client",
        value_keys: &["connect", "requests", "timeout"],
        flag_keys: &["quiet", "stats"],
    },
    CommandSpec {
        name: "report",
        value_keys: &["instances", "presets", "k", "reps", "seed", "workers", "out"],
        flag_keys: &[],
    },
    CommandSpec {
        name: "generate",
        value_keys: &[
            "kind",
            "out",
            "seed",
            "scale",
            "n",
            "edges",
            "attach",
            "ring",
            "beta",
            "rows",
            "cols",
            "avg-degree",
            "mu",
        ],
        flag_keys: &[],
    },
    CommandSpec {
        name: "shard",
        value_keys: &["graph", "instance", "out", "shards", "in", "format"],
        flag_keys: &[],
    },
    CommandSpec {
        name: "evaluate",
        value_keys: &["graph", "instance", "partition", "epsilon"],
        flag_keys: &[],
    },
    CommandSpec {
        name: "stats",
        value_keys: &["graph", "instance"],
        flag_keys: &[],
    },
    CommandSpec {
        name: "offload",
        value_keys: &["graph", "instance", "upper", "rounds"],
        flag_keys: &[],
    },
    CommandSpec {
        name: "presets",
        value_keys: &[],
        flag_keys: &[],
    },
];

/// Look up a subcommand's option schema.
pub fn command_spec(name: &str) -> Option<&'static CommandSpec> {
    COMMANDS.iter().find(|c| c.name == name)
}

/// Bounded Levenshtein distance for did-you-mean suggestions.
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Closest known key within edit distance 2, for error messages.
fn suggest<'a>(key: &str, spec: &'a CommandSpec) -> Option<&'a str> {
    spec.value_keys
        .iter()
        .chain(spec.flag_keys.iter())
        .map(|k| (edit_distance(key, k), *k))
        .filter(|(d, _)| *d <= 2)
        .min_by_key(|(d, _)| *d)
        .map(|(_, k)| k)
}

fn unknown_option(command: &str, key: &str, spec: &CommandSpec) -> String {
    match suggest(key, spec) {
        Some(s) => format!("unknown option --{key} for `{command}` (did you mean --{s}?)"),
        None => format!("unknown option --{key} for `{command}` (see `sclap help`)"),
    }
}

/// Parsed command line: a subcommand plus validated options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub options: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]): the
    /// first token selects the subcommand and its [`CommandSpec`];
    /// unknown subcommands and unknown options are errors.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut iter = args.into_iter();
        let command = iter.next().unwrap_or_default();
        if matches!(command.as_str(), "" | "help" | "--help") {
            return Ok(Args {
                command,
                ..Args::default()
            });
        }
        let spec = command_spec(&command)
            .ok_or_else(|| format!("unknown command {command:?} (try `sclap help`)"))?;
        Self::parse_with_spec(command, iter, spec)
    }

    /// Parse the options of one subcommand against its schema.
    pub fn parse_with_spec<I: IntoIterator<Item = String>>(
        command: String,
        args: I,
        spec: &CommandSpec,
    ) -> Result<Args, String> {
        let mut iter = args.into_iter().peekable();
        let mut options = HashMap::new();
        let mut positional = Vec::new();
        while let Some(arg) = iter.next() {
            if arg == "--" {
                // Explicit end of options: the rest is positional.
                positional.extend(iter);
                break;
            }
            let Some(body) = arg.strip_prefix("--") else {
                positional.push(arg);
                continue;
            };
            let (key, inline_value) = match body.split_once('=') {
                Some((k, v)) => (k.to_string(), Some(v.to_string())),
                None => (body.to_string(), None),
            };
            let takes_value = spec.value_keys.contains(&key.as_str());
            let is_flag = spec.flag_keys.contains(&key.as_str());
            if !takes_value && !is_flag {
                return Err(unknown_option(&command, &key, spec));
            }
            let value = if let Some(v) = inline_value {
                if is_flag && !takes_value {
                    // An inline value on a boolean flag must actually be
                    // a boolean — `--timing=on` silently meaning "off"
                    // is the class of misparse this parser exists to
                    // eliminate. Stored lowercased so `flag()` sees it.
                    let lower = v.to_ascii_lowercase();
                    if !matches!(
                        lower.as_str(),
                        "true" | "false" | "1" | "0" | "yes" | "no"
                    ) {
                        return Err(format!("option --{key}: bad boolean {v:?} (true/false)"));
                    }
                    lower
                } else {
                    v
                }
            } else if takes_value {
                // A value-taking key consumes exactly the next token —
                // which must exist and must not itself be an option.
                match iter.peek() {
                    Some(next) if !next.starts_with("--") => iter.next().unwrap(),
                    _ => return Err(format!("option --{key} needs a value")),
                }
            } else {
                // Boolean flag: never consumes the next token, so a
                // following positional is kept as a positional.
                "true".to_string()
            };
            if options.insert(key.clone(), value).is_some() {
                return Err(format!("duplicate option --{key}"));
            }
        }
        Ok(Args {
            command,
            options,
            positional,
        })
    }

    pub fn parse_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad integer {v:?}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad integer {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad float {v:?}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    fn parse_err(s: &str) -> String {
        Args::parse(s.split_whitespace().map(String::from)).unwrap_err()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = parse("partition --k 8 --preset UFast --graph g.bin");
        assert_eq!(a.command, "partition");
        assert_eq!(a.get("k"), Some("8"));
        assert_eq!(a.get("preset"), Some("UFast"));
        assert_eq!(a.get_usize("k", 2).unwrap(), 8);
    }

    #[test]
    fn boolean_flags() {
        let a = parse("serve --timing --max-pending 3");
        assert!(a.flag("timing"));
        assert!(!a.flag("verbose"));
        assert_eq!(a.get_usize("max-pending", 10).unwrap(), 3);
    }

    #[test]
    fn defaults() {
        let a = parse("stats");
        assert_eq!(a.get_or("graph", "none"), "none");
        assert_eq!(a.get_f64("epsilon", 0.03).unwrap(), 0.03);
        assert_eq!(a.get_u64("seed", 1).unwrap(), 1);
    }

    #[test]
    fn positional_args() {
        let a = parse("stats file1.graph file2.graph");
        assert_eq!(a.positional, vec!["file1.graph", "file2.graph"]);
    }

    #[test]
    fn flag_does_not_swallow_following_positional() {
        // Regression: the old parser attached the next non-`--` token to
        // ANY option, so a boolean flag silently ate a positional.
        let a = parse("partition --parallel-coarsening g.graph");
        assert!(a.flag("parallel-coarsening"));
        assert_eq!(a.positional, vec!["g.graph"]);
    }

    #[test]
    fn key_equals_value_forms() {
        let a = parse("partition --k=8 --preset=UFast --parallel-refinement=false");
        assert_eq!(a.get_usize("k", 2).unwrap(), 8);
        assert_eq!(a.get("preset"), Some("UFast"));
        assert!(!a.flag("parallel-refinement"));
        let b = parse("partition --parallel-refinement=true");
        assert!(b.flag("parallel-refinement"));
    }

    #[test]
    fn flag_inline_values_validated() {
        // `--timing=on` must error, not silently mean "off".
        let e = parse_err("serve --timing=on");
        assert!(e.contains("bad boolean"), "{e}");
        // case-insensitive booleans normalize so `flag()` sees them
        assert!(parse("serve --timing=TRUE").flag("timing"));
        assert!(!parse("serve --timing=No").flag("timing"));
    }

    #[test]
    fn unknown_option_is_an_error_with_suggestion() {
        // Regression: `--memory-bugdet 1g` used to be silently ignored,
        // running fully in-memory with no warning.
        let e = parse_err("partition --memory-bugdet 1g --graph g.bin");
        assert!(e.contains("--memory-bugdet"), "{e}");
        assert!(e.contains("--memory-budget"), "no suggestion in {e:?}");
        // and far-off typos still error, just without a suggestion
        let e2 = parse_err("partition --frobnicate 1");
        assert!(e2.contains("unknown option"), "{e2}");
    }

    #[test]
    fn unknown_options_validated_per_subcommand() {
        // `--reps` is a partition key, not a stats key.
        assert!(parse_err("stats --reps 3").contains("unknown option"));
        assert!(parse("partition --reps 3").get("reps").is_some());
    }

    #[test]
    fn unknown_command_rejected() {
        assert!(parse_err("partitoin --k 8").contains("unknown command"));
    }

    #[test]
    fn help_forms_skip_option_validation() {
        for cmd in ["", "help", "--help"] {
            let a = Args::parse(cmd.split_whitespace().map(String::from)).unwrap();
            assert_eq!(a.command, cmd);
        }
    }

    #[test]
    fn value_key_requires_a_value() {
        assert!(parse_err("partition --k").contains("needs a value"));
        assert!(parse_err("partition --k --preset UFast").contains("needs a value"));
    }

    #[test]
    fn double_dash_ends_options() {
        let a = parse("stats -- --graph");
        assert!(a.options.is_empty());
        assert_eq!(a.positional, vec!["--graph"]);
    }

    #[test]
    fn duplicate_option_rejected() {
        assert!(parse_err("partition --k 1 --k 2").contains("duplicate"));
        assert!(parse_err("partition --k=1 --k 2").contains("duplicate"));
    }

    #[test]
    fn bad_number_reported() {
        let a = parse("partition --k eight");
        assert!(a.get_usize("k", 2).is_err());
    }

    #[test]
    fn negative_single_dash_values_still_accepted() {
        // only `--`-prefixed tokens are refused as values
        let a = parse("partition --seed -3");
        assert_eq!(a.get("seed"), Some("-3"));
    }

    #[test]
    fn config_option_keys_are_all_partition_keys() {
        // `PartitionConfig::apply_option` keys must stay accepted by the
        // `partition` subcommand (value keys or flag keys).
        let spec = command_spec("partition").unwrap();
        for key in crate::partitioning::config::CONFIG_OPTION_KEYS {
            assert!(
                spec.value_keys.contains(key) || spec.flag_keys.contains(key),
                "config option --{key} missing from the partition spec"
            );
        }
    }

    #[test]
    fn main_dispatch_table_covered() {
        // every spec'd command resolves, and the spec table has no dups
        for c in COMMANDS {
            assert_eq!(command_spec(c.name).unwrap().name, c.name);
        }
        let mut names: Vec<&str> = COMMANDS.iter().map(|c| c.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), COMMANDS.len());
    }
}
