//! Semi-external size-constrained label propagation — SCLaP over a
//! [`GraphStore`], after *(Semi-)External Algorithms for Graph
//! Partitioning and Clustering* (arXiv 1404.4887): only node state
//! (labels, cluster weights/counts — O(n)) is resident; adjacency is
//! streamed shard by shard through a [`ShardCursor`], at most one shard
//! in RAM.
//!
//! # Schedule (and why it is shard- and thread-invariant)
//!
//! Each round walks the **global node range in natural order**, split
//! into fixed [`STREAM_CHUNK`]-node chunks:
//!
//! 1. **Score** every node of the chunk against the label/size state
//!    left by the previous chunk — the sequential engine's move rule
//!    (strongest eligible neighboring cluster, size bound `U`, ties by
//!    reservoir sampling), evaluated as a pure function and fanned out
//!    on the shared pool in fixed [`SCORE_CHUNK`] slices. Each node's
//!    tie-break RNG stream derives from `(round seed, node id)` via
//!    [`derive_seed`], so the proposal set is independent of *any*
//!    decomposition — pool size, scoring slice, or shard boundary.
//!    Scoring scratch is **degree-bounded** (sorted neighbor-label
//!    runs, candidates visited in ascending label order), not an
//!    O(n) per-worker table — per-worker memory stays O(max degree),
//!    preserving the O(n)-node-state budget at any thread count.
//! 2. **Apply** the chunk's proposals sequentially against the live
//!    size table in **degree order** (highest scored degree first, ties
//!    by node id — the sequential engine's degree-order heuristic,
//!    applied per chunk: well-connected nodes claim cluster capacity
//!    before leaves do), re-checking eligibility (a target that filled
//!    up since scoring is skipped), so the bound holds exactly after
//!    every chunk — the same proposal/apply discipline as
//!    `clustering::async_lpa`. Degrees come from the scoring pass, so
//!    the ordering adds no extra shard traffic.
//!
//! The scoring pass is cache-conscious: proposals and degrees land in
//! two flat chunk-sized `u32` arrays through disjoint per-slice windows
//! ([`DisjointSlice`]) — no per-slice `Vec`s, no gather step, no
//! allocation anywhere in the round loop once the per-worker scratch
//! has warmed up. [`score_node`] aggregates neighbor labels by sorting
//! the gathered pairs and compressing equal-label runs in place, then
//! scans the compressed runs once, branch-light; its tie-break RNG is
//! constructed lazily ([`Rng::new`] is a pure seed expansion, so a
//! node whose scan never reaches a tie skips the expansion entirely
//! without perturbing the draw sequence of one that does).
//!
//! A chunk whose node range crosses a shard boundary is scored in two
//! sub-ranges (old shard, then new shard) with **no applies in
//! between** — both sub-scorings read the same state, so shard
//! boundaries are unobservable in the output. The cursor therefore
//! advances strictly forward: each round streams each shard exactly
//! once. The hard invariant (asserted by `rust/tests/sharded_store.rs`):
//! same seed + config ⇒ byte-identical labels for any shard count and
//! any thread count, and for [`InMemoryStore`](crate::graph::store)
//! versus [`ShardedStore`](crate::graph::store) backends.
//!
//! Like the other parallel engines this is a *different algorithm* from
//! the sequential `size_constrained_lpa` (natural-order chunk streaming
//! with per-chunk degree-ordered applies instead of one global degree
//! order, chunk-snapshot eligibility): it is selected by configuration
//! (`PartitionConfig::memory_budget_bytes`), never by input size
//! probing, thread count, or storage backend.

use crate::clustering::label_propagation::{Clustering, LpaConfig, LpaMode};
use crate::graph::csr::{NodeId, Weight};
use crate::graph::store::{GraphStore, ShardView};
use crate::obs::trace;
use crate::partitioning::workspace::VcycleWorkspace;
use crate::util::exec::{derive_seed, ExecutionCtx};
use crate::util::pool::{DisjointSlice, ThreadPool};
use crate::util::rng::Rng;
use std::io;

/// Nodes per score→apply chunk. Fixed — part of the logical schedule,
/// never derived from the thread count, shard count, or input size.
pub const STREAM_CHUNK: usize = 2048;

/// Nodes per pool scoring slice within a chunk. Also fixed; with
/// per-node RNG streams the slicing is unobservable anyway, this only
/// sizes the dispatch.
const SCORE_CHUNK: usize = 256;

/// "No proposal" marker in the flat proposal array. Safe as a sentinel:
/// labels are node ids (< n ≤ `u32::MAX`, so ids stop at
/// `u32::MAX - 1`) or block ids (< k ≤ n) — a real label never equals
/// `u32::MAX`, which would require a 2^32-entry resident cluster table
/// anyway.
const STAY: u32 = u32::MAX;

/// Run semi-external SCLaP on `store`.
///
/// * `upper_bound` — `U`: no cluster's weight may exceed it (must be at
///   least the maximum node weight; asserted).
/// * `initial` — starting labels (`None` ⇒ singletons, clustering mode
///   only). Refinement mode requires the current partition and applies
///   the overloaded-block and never-empty rules of the sequential
///   engine.
///
/// Returns the **raw** final labels (refinement callers keep their
/// block ids; coarsening callers densify via [`dense_from_labels`])
/// and the number of rounds executed.
pub fn external_sclap(
    store: &dyn GraphStore,
    upper_bound: Weight,
    config: &LpaConfig,
    initial: Option<Vec<u32>>,
    ctx: &ExecutionCtx,
    rng: &mut Rng,
) -> io::Result<(Vec<u32>, usize)> {
    let n = store.n();
    let node_weights = store.node_weights();
    assert!(
        upper_bound >= store.max_node_weight(),
        "U={} below max node weight {}",
        upper_bound,
        store.max_node_weight()
    );
    let mut labels: Vec<u32> = match initial {
        Some(init) => {
            assert_eq!(init.len(), n);
            init
        }
        None => {
            assert_eq!(config.mode, LpaMode::Clustering);
            (0..n as u32).collect()
        }
    };

    // Resident cluster state, indexed by (possibly sparse) label. Pure
    // working state (only `labels` escapes), so it leases from the
    // workspace — a `serve` daemon's warm requests reuse the same
    // tables instead of re-allocating O(n) per request.
    let ws = ctx.workspace();
    let max_label = labels.iter().copied().max().unwrap_or(0) as usize;
    let table = (max_label + 1).max(n).max(1);
    let mut cluster_weight = ws.caller().lease::<Vec<Weight>>(table);
    cluster_weight.resize(table, 0);
    let mut cluster_count = ws.caller().lease::<Vec<u32>>(table);
    cluster_count.resize(table, 0);
    for v in 0..n {
        cluster_weight[labels[v] as usize] += node_weights[v];
        cluster_count[labels[v] as usize] += 1;
    }

    let pool = ctx.pool();

    // Flat chunk-sized proposal/degree arrays plus the apply order,
    // leased once here and reused by every chunk of every round — the
    // round loop is allocation-free after warm-up.
    let mut prop_target = ws.caller().lease::<Vec<u32>>(STREAM_CHUNK);
    prop_target.resize(STREAM_CHUNK, STAY);
    let mut prop_degree = ws.caller().lease::<Vec<u32>>(STREAM_CHUNK);
    prop_degree.resize(STREAM_CHUNK, 0);
    let mut order = ws.caller().lease::<Vec<u32>>(STREAM_CHUNK);

    let mut cursor = store.cursor();
    let mut rounds = 0usize;
    let mut converged = false;
    while rounds < config.max_iterations {
        crate::util::cancel::checkpoint();
        rounds += 1;
        let round_seed = rng.next_u64();
        let mut changed = 0usize;
        let mut shard = 0usize;
        let mut chunk_lo = 0usize;
        while chunk_lo < n {
            let chunk_hi = (chunk_lo + STREAM_CHUNK).min(n);
            let chunk_len = chunk_hi - chunk_lo;
            // ---- score (possibly split at shard boundaries; the state
            // is identical for every split, so the split is invisible).
            // Every slot in 0..chunk_len is written, so no reset needed.
            {
                let proposals = DisjointSlice::new(&mut prop_target[..chunk_len]);
                let degrees = DisjointSlice::new(&mut prop_degree[..chunk_len]);
                let mut start = chunk_lo;
                while start < chunk_hi {
                    while store.shard_span(shard).1 <= start {
                        shard += 1;
                    }
                    let stop = chunk_hi.min(store.shard_span(shard).1);
                    let view = cursor.load(shard)?;
                    score_range(
                        &view,
                        node_weights,
                        &labels,
                        &cluster_weight,
                        &cluster_count,
                        upper_bound,
                        config.mode,
                        start,
                        stop,
                        chunk_lo,
                        round_seed,
                        pool,
                        ws,
                        &proposals,
                        &degrees,
                    );
                    start = stop;
                }
            }
            // ---- apply against the live size table, movers in degree
            // order (highest first, ties by node id — deterministic).
            order.clear();
            for (i, &target) in prop_target[..chunk_len].iter().enumerate() {
                if target != STAY {
                    order.push(i as u32);
                }
            }
            order.sort_unstable_by(|&a, &b| {
                prop_degree[b as usize]
                    .cmp(&prop_degree[a as usize])
                    .then(a.cmp(&b))
            });
            for &i in order.iter() {
                let vi = chunk_lo + i as usize;
                let target = prop_target[i as usize];
                let cur = labels[vi];
                if cur == target {
                    continue;
                }
                let vw = node_weights[vi];
                if cluster_weight[target as usize] + vw > upper_bound {
                    continue; // filled up since scoring
                }
                if config.mode == LpaMode::Refinement && cluster_count[cur as usize] <= 1 {
                    continue; // blocks must never empty
                }
                cluster_weight[cur as usize] -= vw;
                cluster_weight[target as usize] += vw;
                cluster_count[cur as usize] -= 1;
                cluster_count[target as usize] += 1;
                labels[vi] = target;
                changed += 1;
            }
            chunk_lo = chunk_hi;
        }
        debug_assert!(
            config.mode == LpaMode::Refinement
                || cluster_weight.iter().all(|&w| w <= upper_bound)
        );
        trace::counter(
            "external_lpa_round",
            &[("round", rounds as i64), ("moved", changed as i64)],
        );
        if (changed as f64) < config.convergence_fraction * n as f64 {
            converged = true;
            break;
        }
    }
    let reason = if converged {
        crate::obs::quality::STOP_CONVERGED
    } else {
        crate::obs::quality::STOP_MAX_ITERATIONS
    };
    trace::counter(
        "external_lpa_done",
        &[("rounds", rounds as i64), ("reason", reason)],
    );
    Ok((labels, rounds))
}

/// Score nodes `start..stop` (all inside `view`'s span) on the pool,
/// writing each node's proposal ([`STAY`] for none) and degree into the
/// chunk-relative slots `start - chunk_lo ..` of the flat output
/// arrays. Slices write disjoint windows — no per-slice allocation, no
/// gather.
#[allow(clippy::too_many_arguments)]
fn score_range(
    view: &ShardView<'_>,
    node_weights: &[Weight],
    labels: &[u32],
    cluster_weight: &[Weight],
    cluster_count: &[u32],
    upper_bound: Weight,
    mode: LpaMode,
    start: usize,
    stop: usize,
    chunk_lo: usize,
    round_seed: u64,
    pool: &ThreadPool,
    ws: &VcycleWorkspace,
    proposals: &DisjointSlice<'_, u32>,
    degrees: &DisjointSlice<'_, u32>,
) {
    let len = stop - start;
    let num_slices = len.div_ceil(SCORE_CHUNK);
    pool.run(num_slices, |worker, slice| {
        let lo = start + slice * SCORE_CHUNK;
        let hi = (lo + SCORE_CHUNK).min(stop);
        // Degree-bounded gather scratch, leased from the executing
        // worker's arena shard (steady state: same buffer every slice,
        // no allocation) — never O(n) per worker.
        let mut pairs = ws.worker(worker).lease::<Vec<(u32, Weight)>>(0);
        // SAFETY: slices cover disjoint node ranges of the chunk, so
        // their chunk-relative windows are disjoint too.
        let props = unsafe { proposals.range_mut(lo - chunk_lo, hi - chunk_lo) };
        let degs = unsafe { degrees.range_mut(lo - chunk_lo, hi - chunk_lo) };
        for (off, v) in (lo..hi).enumerate() {
            let proposal = score_node(
                view,
                node_weights,
                labels,
                cluster_weight,
                cluster_count,
                upper_bound,
                mode,
                v as NodeId,
                derive_seed(round_seed, v as u64),
                &mut pairs,
            );
            props[off] = proposal.unwrap_or(STAY);
            degs[off] = view.degree(v as NodeId) as u32;
        }
    });
}

/// The sequential engine's move rule as a pure function: strongest
/// eligible neighboring cluster under the chunk-start state, ties by
/// reservoir sampling on a per-node RNG stream. Returns the proposed
/// target, or `None` to stay.
///
/// Connection aggregation is degree-bounded and branch-light: neighbor
/// (label, weight) pairs are gathered into `pairs` (worker scratch),
/// sorted by label, and equal-label runs are **compressed in place**,
/// so the candidate scan is one pass over at most `degree` compressed
/// runs with no inner accumulation loop; the stay connection comes from
/// a binary search over the sorted runs. O(max degree) scratch instead
/// of an O(n) per-worker table; candidates appear in ascending label
/// order, a pure function of the inputs. The tie-break RNG is built
/// lazily at the first tie — [`Rng::new`] is a pure seed expansion, so
/// the draw sequence is identical to eager construction.
#[allow(clippy::too_many_arguments)]
fn score_node(
    view: &ShardView<'_>,
    node_weights: &[Weight],
    labels: &[u32],
    cluster_weight: &[Weight],
    cluster_count: &[u32],
    upper_bound: Weight,
    mode: LpaMode,
    v: NodeId,
    seed: u64,
    pairs: &mut Vec<(u32, Weight)>,
) -> Option<u32> {
    let vi = v as usize;
    let cur = labels[vi];
    let (adj, ws) = view.adjacent(v);
    if adj.is_empty() {
        return None;
    }
    if mode == LpaMode::Refinement && cluster_count[cur as usize] <= 1 {
        return None; // refinement must not empty a block
    }
    let vw = node_weights[vi];
    pairs.clear();
    pairs.extend(adj.iter().zip(ws).map(|(&u, &w)| (labels[u as usize], w)));
    pairs.sort_unstable_by_key(|&(label, _)| label);
    // In-place run compression: pairs[..runs] becomes one
    // (label, total connection) entry per distinct neighbor label,
    // still ascending by label.
    let mut runs = 0usize;
    let mut i = 0usize;
    while i < pairs.len() {
        let (label, w) = pairs[i];
        if runs > 0 && pairs[runs - 1].0 == label {
            pairs[runs - 1].1 += w;
        } else {
            pairs[runs] = (label, w);
            runs += 1;
        }
        i += 1;
    }
    let overloaded = mode == LpaMode::Refinement && cluster_weight[cur as usize] > upper_bound;
    // Overloaded-block rule: an overloaded block's nodes must consider
    // only other blocks; otherwise staying is an option with the
    // connection to `cur`.
    let stay: Weight = match pairs[..runs].binary_search_by_key(&cur, |&(label, _)| label) {
        Ok(idx) => pairs[idx].1,
        Err(_) => 0,
    };
    let mut rng: Option<Rng> = None;
    let mut best_conn: i64 = if overloaded { i64::MIN } else { stay };
    let mut best: u32 = cur;
    let mut ties: u32 = 1;
    for &(label, conn) in &pairs[..runs] {
        if label == cur || cluster_weight[label as usize] + vw > upper_bound {
            continue;
        }
        if conn > best_conn {
            best_conn = conn;
            best = label;
            ties = 1;
        } else if conn == best_conn && best_conn > i64::MIN {
            ties += 1;
            if rng.get_or_insert_with(|| Rng::new(seed)).below(ties as usize) == 0 {
                best = label;
            }
        }
    }
    (best != cur).then_some(best)
}

/// Densify raw labels into a [`Clustering`] (dense ids `0..nc` by first
/// occurrence, cluster weights summed from the resident node weights) —
/// the store-side equivalent of `Clustering::from_labels`, needing no
/// materialized graph.
pub fn dense_from_labels(node_weights: &[Weight], mut labels: Vec<u32>) -> Clustering {
    let mut remap: Vec<u32> = vec![u32::MAX; labels.len().max(1)];
    let mut next = 0u32;
    for l in labels.iter_mut() {
        let slot = *l as usize;
        if remap[slot] == u32::MAX {
            remap[slot] = next;
            next += 1;
        }
        *l = remap[slot];
    }
    let num_clusters = next as usize;
    let mut cluster_weights = vec![0 as Weight; num_clusters];
    for (v, &l) in labels.iter().enumerate() {
        cluster_weights[l as usize] += node_weights[v];
    }
    Clustering {
        labels,
        num_clusters,
        cluster_weights,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::label_propagation::NodeOrdering;
    use crate::generators;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::csr::Graph;
    use crate::graph::store::InMemoryStore;

    fn clustering_cfg(iters: usize) -> LpaConfig {
        LpaConfig::clustering(iters, NodeOrdering::Degree)
    }

    fn run_labels(g: &Graph, shards: usize, threads: usize, seed: u64) -> Vec<u32> {
        let store = InMemoryStore::with_shards(g, shards);
        let ctx = ExecutionCtx::new(threads);
        let upper = (g.total_node_weight() / 16).max(g.max_node_weight()).max(1);
        external_sclap(
            &store,
            upper,
            &clustering_cfg(5),
            None,
            &ctx,
            &mut Rng::new(seed),
        )
        .unwrap()
        .0
    }

    #[test]
    fn finds_clique_structure() {
        // Two K4s joined by one edge.
        let mut b = GraphBuilder::new(8);
        for base in [0u32, 4] {
            for i in 0..4 {
                for j in (i + 1)..4 {
                    b.add_edge(base + i, base + j, 1);
                }
            }
        }
        b.add_edge(3, 4, 1);
        let g = b.build();
        let store = InMemoryStore::new(&g);
        let ctx = ExecutionCtx::sequential();
        let (labels, _) =
            external_sclap(&store, 4, &clustering_cfg(10), None, &ctx, &mut Rng::new(3))
                .unwrap();
        let c = dense_from_labels(g.node_weights(), labels);
        assert_eq!(c.num_clusters, 2);
        assert!((1..4).all(|i| c.labels[i] == c.labels[0]));
        assert!((5..8).all(|i| c.labels[i] == c.labels[4]));
        assert_eq!(c.cut(&g), 1);
    }

    #[test]
    fn labels_invariant_across_shards_and_threads() {
        let mut rng = Rng::new(7);
        let g = generators::barabasi_albert(3000, 4, &mut rng);
        let reference = run_labels(&g, 1, 1, 11);
        assert!(
            reference.iter().collect::<std::collections::HashSet<_>>().len() < g.n(),
            "no clustering happened"
        );
        for shards in [2usize, 3, 7, 8] {
            for threads in [1usize, 4] {
                assert_eq!(
                    reference,
                    run_labels(&g, shards, threads, 11),
                    "shards={shards} threads={threads} diverged"
                );
            }
        }
    }

    #[test]
    fn respects_bound_for_many_seeds() {
        let mut rng = Rng::new(5);
        let g = generators::barabasi_albert(600, 3, &mut rng);
        let store = InMemoryStore::with_shards(&g, 3);
        let ctx = ExecutionCtx::new(2);
        for seed in 0..6 {
            let (labels, _) = external_sclap(
                &store,
                20,
                &clustering_cfg(5),
                None,
                &ctx,
                &mut Rng::new(seed),
            )
            .unwrap();
            let c = dense_from_labels(g.node_weights(), labels);
            assert!(c.respects_bound(20), "seed {seed}: {:?}", c.cluster_weights);
        }
    }

    #[test]
    fn refinement_reduces_cut_and_keeps_blocks() {
        let mut rng = Rng::new(9);
        let g = generators::barabasi_albert(800, 3, &mut rng);
        // Bad initial 2-partition by parity.
        let initial: Vec<u32> = (0..g.n() as u32).map(|v| v % 2).collect();
        let before = crate::partitioning::metrics::cut_value(&g, &initial);
        let store = InMemoryStore::with_shards(&g, 4);
        let ctx = ExecutionCtx::sequential();
        let upper = (g.total_node_weight() * 11 / 20).max(g.max_node_weight());
        let mut cfg = LpaConfig::refinement(10);
        cfg.active_nodes = false; // streaming engine has no queue variant
        let (refined, _) = external_sclap(
            &store,
            upper,
            &cfg,
            Some(initial),
            &ctx,
            &mut Rng::new(2),
        )
        .unwrap();
        let after = crate::partitioning::metrics::cut_value(&g, &refined);
        assert!(after < before, "cut {after} !< {before}");
        // Still exactly two non-empty blocks with ids < 2.
        assert!(refined.iter().all(|&b| b < 2));
        assert!(refined.iter().any(|&b| b == 0));
        assert!(refined.iter().any(|&b| b == 1));
        // Balance bound respected.
        let w0: i64 = refined
            .iter()
            .enumerate()
            .filter(|&(_, &b)| b == 0)
            .map(|(v, _)| g.node_weight(v as u32))
            .sum();
        assert!(w0 <= upper && (g.total_node_weight() - w0) <= upper);
    }

    #[test]
    fn isolated_nodes_stay_put() {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 1);
        let g = b.build();
        let store = InMemoryStore::new(&g);
        let ctx = ExecutionCtx::sequential();
        let (labels, _) =
            external_sclap(&store, 4, &clustering_cfg(5), None, &ctx, &mut Rng::new(1))
                .unwrap();
        let c = dense_from_labels(g.node_weights(), labels);
        assert!(c.num_clusters >= 3);
    }

    #[test]
    fn dense_from_labels_matches_clustering_from_labels() {
        let mut rng = Rng::new(13);
        let g = generators::erdos_renyi(200, 600, &mut rng);
        let labels: Vec<u32> = (0..g.n() as u32).map(|v| (v * 7) % 13).collect();
        let a = dense_from_labels(g.node_weights(), labels.clone());
        let b = Clustering::from_labels(&g, labels);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.num_clusters, b.num_clusters);
        assert_eq!(a.cluster_weights, b.cluster_weights);
    }
}
