//! Shared-memory parallel SCLaP — the paper's §6 future-work direction
//! ("label propagation … has a large potential to be efficiently
//! parallelized"), implemented with std::thread.
//!
//! Semantics match the accelerator offload path (`runtime::dense_lpa`):
//! each round is *synchronous* — worker threads score all nodes against a
//! snapshot of the labels, then the proposals are reconciled sequentially
//! in descending-gain order against a live cluster-size table, so the
//! size constraint holds exactly (invariant 7 of DESIGN.md §7).

use crate::graph::csr::{Graph, NodeId, Weight};
use crate::util::fast_reset::FastResetArray;
use crate::util::rng::Rng;

use super::label_propagation::Clustering;

/// A proposed move produced by the scoring pass.
#[derive(Debug, Clone, Copy)]
pub struct Proposal {
    pub node: NodeId,
    pub target: u32,
    /// Connection-strength improvement vs. staying (snapshot gain).
    pub gain: i64,
}

/// Score one chunk of nodes against the label snapshot. Pure function —
/// safe to run on worker threads with shared read-only state.
fn score_chunk(
    g: &Graph,
    labels: &[u32],
    cluster_weight: &[Weight],
    upper_bound: Weight,
    chunk: &[NodeId],
    seed: u64,
) -> Vec<Proposal> {
    let mut conn: FastResetArray<i64> = FastResetArray::new(cluster_weight.len());
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for &v in chunk {
        let cur = labels[v as usize];
        let vw = g.node_weight(v);
        let adj = g.adjacent(v);
        if adj.is_empty() {
            continue;
        }
        let ws = g.adjacent_weights(v);
        conn.clear();
        for (&u, &w) in adj.iter().zip(ws) {
            conn.accumulate(labels[u as usize] as usize, w);
        }
        let stay = conn.get(cur as usize);
        let mut best = cur;
        let mut best_conn = stay;
        let mut ties = 1u32;
        for &c in conn.touched() {
            let c32 = c as u32;
            if c32 == cur || cluster_weight[c] + vw > upper_bound {
                continue;
            }
            let s = conn.value_of_touched(c);
            if s > best_conn {
                best = c32;
                best_conn = s;
                ties = 1;
            } else if s == best_conn {
                ties += 1;
                if rng.below(ties as usize) == 0 {
                    best = c32;
                }
            }
        }
        if best != cur && best_conn > stay {
            out.push(Proposal {
                node: v,
                target: best,
                gain: best_conn - stay,
            });
        }
    }
    out
}

/// Apply proposals in descending-gain order against the live size table.
/// Returns the number of applied moves. Shared with the PJRT offload path.
pub fn reconcile_proposals(
    g: &Graph,
    labels: &mut [u32],
    cluster_weight: &mut [Weight],
    upper_bound: Weight,
    proposals: &mut Vec<Proposal>,
) -> usize {
    proposals.sort_unstable_by(|a, b| b.gain.cmp(&a.gain).then(a.node.cmp(&b.node)));
    let mut applied = 0;
    for p in proposals.iter() {
        let v = p.node as usize;
        let vw = g.node_weight(p.node);
        if labels[v] == p.target {
            continue;
        }
        if cluster_weight[p.target as usize] + vw > upper_bound {
            continue; // became ineligible after earlier accepted moves
        }
        cluster_weight[labels[v] as usize] -= vw;
        cluster_weight[p.target as usize] += vw;
        labels[v] = p.target;
        applied += 1;
    }
    applied
}

/// Parallel size-constrained LPA (clustering mode, singleton start).
pub fn parallel_sclap(
    g: &Graph,
    upper_bound: Weight,
    max_iterations: usize,
    threads: usize,
    rng: &mut Rng,
) -> Clustering {
    let n = g.n();
    assert!(upper_bound >= g.max_node_weight());
    let threads = threads.max(1);
    let mut labels: Vec<u32> = (0..n as u32).collect();
    let mut cluster_weight: Vec<Weight> = g.node_weights().to_vec();

    for _round in 0..max_iterations {
        let nodes: Vec<NodeId> = (0..n as NodeId).collect();
        let chunk_size = n.div_ceil(threads).max(1);
        let seeds: Vec<u64> = (0..threads).map(|_| rng.next_u64()).collect();

        let mut proposals: Vec<Proposal> = Vec::new();
        std::thread::scope(|scope| {
            let labels_ref: &[u32] = &labels;
            let weights_ref: &[Weight] = &cluster_weight;
            let handles: Vec<_> = nodes
                .chunks(chunk_size)
                .zip(seeds.iter())
                .map(|(chunk, &seed)| {
                    scope.spawn(move || {
                        score_chunk(g, labels_ref, weights_ref, upper_bound, chunk, seed)
                    })
                })
                .collect();
            for h in handles {
                proposals.extend(h.join().expect("scoring thread panicked"));
            }
        });

        let applied = reconcile_proposals(
            g,
            &mut labels,
            &mut cluster_weight,
            upper_bound,
            &mut proposals,
        );
        if (applied as f64) < 0.05 * n as f64 {
            break;
        }
    }

    Clustering::from_labels(g, labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators;
    use crate::graph::karate::karate_club;

    #[test]
    fn parallel_respects_bound() {
        let g = karate_club();
        for threads in [1, 2, 4] {
            let mut rng = Rng::new(1);
            let c = parallel_sclap(&g, 6, 10, threads, &mut rng);
            assert!(c.respects_bound(6), "threads={threads}: {:?}", c.cluster_weights);
        }
    }

    #[test]
    fn parallel_finds_structure() {
        let mut rng = Rng::new(2);
        let g = generators::barabasi_albert(2000, 4, &mut rng);
        let c = parallel_sclap(&g, 50, 10, 4, &mut Rng::new(3));
        assert!(c.num_clusters < g.n() / 2, "nc={}", c.num_clusters);
        assert!(c.respects_bound(50));
    }

    #[test]
    fn single_thread_equals_sequential_reconciliation() {
        // With 1 thread the proposals are deterministic per seed; rerun
        // must produce identical labels.
        let mut rng = Rng::new(4);
        let g = generators::rmat(9, 2000, 0.57, 0.19, 0.19, &mut rng);
        let a = parallel_sclap(&g, 30, 5, 1, &mut Rng::new(7)).labels;
        let b = parallel_sclap(&g, 30, 5, 1, &mut Rng::new(7)).labels;
        assert_eq!(a, b);
    }

    #[test]
    fn reconcile_skips_ineligible() {
        let g = karate_club();
        let mut labels: Vec<u32> = (0..34).collect();
        let mut weights: Vec<Weight> = vec![1; 34];
        // Two proposals targeting cluster 0 with U=2: only one fits.
        let mut props = vec![
            Proposal { node: 5, target: 0, gain: 3 },
            Proposal { node: 6, target: 0, gain: 2 },
        ];
        let applied = reconcile_proposals(&g, &mut labels, &mut weights, 2, &mut props);
        assert_eq!(applied, 1);
        assert_eq!(labels[5], 0); // higher gain won
        assert_eq!(labels[6], 6);
        assert_eq!(weights[0], 2);
    }
}
