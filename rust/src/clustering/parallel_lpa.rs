//! Pool-parallel synchronous SCLaP — the paper's §6 future-work
//! direction ("label propagation … has a large potential to be
//! efficiently parallelized"), running on the shared deterministic
//! [`ThreadPool`] instead of spawning threads per round.
//!
//! Semantics match the accelerator offload path (`runtime::dense_lpa`):
//! each round is *synchronous* — pool workers score fixed-size node
//! chunks against a snapshot of the labels, then the proposals are
//! reconciled sequentially in descending-gain order against a live
//! cluster-size table, so the size constraint holds exactly (invariant 7
//! of DESIGN.md §7).
//!
//! Determinism: the chunk decomposition uses [`SCORING_CHUNK`] (a fixed
//! constant, *not* the thread count) and every chunk scores with an RNG
//! stream seeded by `(round seed, chunk index)`. The proposal set — and
//! therefore the final labels — is bit-identical for every pool size;
//! `rust/tests/properties.rs` and `rust/tests/determinism.rs` enforce
//! this.

use crate::graph::csr::{Graph, NodeId, Weight};
use crate::obs::trace;
use crate::partitioning::workspace::VcycleWorkspace;
use crate::util::exec::ExecutionCtx;
use crate::util::fast_reset::FastResetArray;
use crate::util::pool::{ThreadPool, WorkerLocal};
use crate::util::rng::Rng;

use super::label_propagation::Clustering;

/// Nodes per scoring chunk. Fixed so the work decomposition — and with
/// it every per-chunk RNG stream — is independent of the thread count
/// (the pool's determinism contract, `util::pool` module docs).
pub const SCORING_CHUNK: usize = 512;

/// Which role a synchronous round plays (mirrors `LpaMode` for the
/// sequential engine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncMode {
    /// Coarsening clustering: move only on strictly positive gain.
    Clustering,
    /// Local search on a partition: the overloaded-block rule applies
    /// (an overloaded block's nodes must consider other blocks even at
    /// negative gain) and blocks are never emptied.
    Refinement,
}

/// A proposed move produced by the scoring pass.
#[derive(Debug, Clone, Copy)]
pub struct Proposal {
    pub node: NodeId,
    pub target: u32,
    /// Connection-strength improvement vs. staying (snapshot gain).
    pub gain: i64,
}

/// Derive the RNG seed of one scoring chunk from the round seed. Pure
/// function of (round, chunk) — never of the executing worker.
#[inline]
fn chunk_seed(round_seed: u64, chunk: usize) -> u64 {
    round_seed ^ (chunk as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Score one node range against the label snapshot. Pure function —
/// safe to run on pool workers with shared read-only state.
#[allow(clippy::too_many_arguments)]
fn score_range(
    g: &Graph,
    labels: &[u32],
    cluster_weight: &[Weight],
    upper_bound: Weight,
    range: std::ops::Range<usize>,
    seed: u64,
    mode: SyncMode,
    conn: &mut FastResetArray<i64>,
) -> Vec<Proposal> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for v in range {
        let v = v as NodeId;
        let cur = labels[v as usize];
        let vw = g.node_weight(v);
        let adj = g.adjacent(v);
        if adj.is_empty() {
            continue;
        }
        let ws = g.adjacent_weights(v);
        conn.clear();
        for (&u, &w) in adj.iter().zip(ws) {
            conn.accumulate(labels[u as usize] as usize, w);
        }
        let stay = conn.get(cur as usize);
        let overloaded =
            mode == SyncMode::Refinement && cluster_weight[cur as usize] > upper_bound;
        let mut best = cur;
        let mut best_conn = if overloaded { i64::MIN } else { stay };
        let mut ties = 1u32;
        for &c in conn.touched() {
            let c32 = c as u32;
            if c32 == cur || cluster_weight[c] + vw > upper_bound {
                continue;
            }
            let s = conn.value_of_touched(c);
            if s > best_conn {
                best = c32;
                best_conn = s;
                ties = 1;
            } else if s == best_conn && best_conn > i64::MIN {
                ties += 1;
                if rng.below(ties as usize) == 0 {
                    best = c32;
                }
            }
        }
        let improves = if overloaded {
            best != cur // any eligible escape route counts
        } else {
            best != cur && best_conn > stay
        };
        if improves {
            out.push(Proposal {
                node: v,
                target: best,
                gain: best_conn - stay,
            });
        }
    }
    out
}

/// Apply proposals in descending-gain order against the live size table.
/// Returns the number of applied moves. Shared with the PJRT offload
/// path (clustering semantics: no block-count bookkeeping).
pub fn reconcile_proposals(
    g: &Graph,
    labels: &mut [u32],
    cluster_weight: &mut [Weight],
    upper_bound: Weight,
    proposals: &mut Vec<Proposal>,
) -> usize {
    apply_proposals(g, labels, cluster_weight, None, upper_bound, proposals)
}

/// Reconcile with optional per-cluster cardinality tracking (refinement
/// must never empty a block).
fn apply_proposals(
    g: &Graph,
    labels: &mut [u32],
    cluster_weight: &mut [Weight],
    mut cluster_count: Option<&mut [u32]>,
    upper_bound: Weight,
    proposals: &mut Vec<Proposal>,
) -> usize {
    proposals.sort_unstable_by(|a, b| b.gain.cmp(&a.gain).then(a.node.cmp(&b.node)));
    let mut applied = 0;
    for p in proposals.iter() {
        let v = p.node as usize;
        let vw = g.node_weight(p.node);
        let from = labels[v];
        if from == p.target {
            continue;
        }
        if let Some(counts) = cluster_count.as_deref_mut() {
            if counts[from as usize] <= 1 {
                continue; // never empty a block (refinement)
            }
        }
        if cluster_weight[p.target as usize] + vw > upper_bound {
            continue; // became ineligible after earlier accepted moves
        }
        cluster_weight[from as usize] -= vw;
        cluster_weight[p.target as usize] += vw;
        if let Some(counts) = cluster_count.as_deref_mut() {
            counts[from as usize] -= 1;
            counts[p.target as usize] += 1;
        }
        labels[v] = p.target;
        applied += 1;
    }
    applied
}

/// Where a synchronous round gets its per-worker connection
/// accumulators from.
///
/// `Workspace` leases one accumulator per scoring chunk from the
/// executing worker's arena shard — in the steady state the shard hands
/// the same buffer back every round, so repeated rounds allocate
/// nothing. `Local` is the caller-owned [`WorkerLocal`] pool (the
/// pre-workspace contract: one accumulator per pool worker, each with
/// capacity ≥ the number of distinct labels).
#[derive(Clone, Copy)]
pub enum RoundScratch<'a> {
    Workspace(&'a VcycleWorkspace),
    Local(&'a WorkerLocal<FastResetArray<i64>>),
}

/// One synchronous SCLaP round on the pool: snapshot-score all nodes in
/// fixed chunks, then reconcile sequentially. Returns applied moves.
#[allow(clippy::too_many_arguments)]
pub fn synchronous_round(
    g: &Graph,
    labels: &mut [u32],
    cluster_weight: &mut [Weight],
    cluster_count: Option<&mut [u32]>,
    upper_bound: Weight,
    mode: SyncMode,
    pool: &ThreadPool,
    scratch: RoundScratch<'_>,
    round_seed: u64,
) -> usize {
    let n = g.n();
    let table = cluster_weight.len().max(1);
    let num_chunks = n.div_ceil(SCORING_CHUNK).max(1);
    let per_chunk: Vec<Vec<Proposal>> = {
        let labels_ref: &[u32] = labels;
        let weights_ref: &[Weight] = cluster_weight;
        pool.map_indexed(num_chunks, |worker, chunk| {
            let lo = chunk * SCORING_CHUNK;
            let hi = (lo + SCORING_CHUNK).min(n);
            let mut conn_l = match scratch {
                RoundScratch::Workspace(ws) => {
                    Some(ws.worker(worker).lease::<FastResetArray<i64>>(table))
                }
                RoundScratch::Local(_) => None,
            };
            let conn: &mut FastResetArray<i64> = match (conn_l.as_mut(), scratch) {
                (Some(l), _) => &mut **l,
                // SAFETY: `worker` is the pool-provided worker id; at
                // most one task runs per id at a time (WorkerLocal
                // contract).
                (None, RoundScratch::Local(wl)) => unsafe { wl.get_mut(worker) },
                (None, RoundScratch::Workspace(_)) => unreachable!(),
            };
            score_range(
                g,
                labels_ref,
                weights_ref,
                upper_bound,
                lo..hi,
                chunk_seed(round_seed, chunk),
                mode,
                conn,
            )
        })
    };
    // Flatten in chunk order — part of the deterministic schedule.
    let mut proposals: Vec<Proposal> = per_chunk.into_iter().flatten().collect();
    apply_proposals(
        g,
        labels,
        cluster_weight,
        cluster_count,
        upper_bound,
        &mut proposals,
    )
}

/// Pool-parallel size-constrained LPA (clustering mode, singleton
/// start) on the shared [`ExecutionCtx`]. Bit-identical output for any
/// pool size, given the same seed stream in `rng`.
pub fn parallel_sclap(
    g: &Graph,
    upper_bound: Weight,
    max_iterations: usize,
    ctx: &ExecutionCtx,
    rng: &mut Rng,
) -> Clustering {
    let n = g.n();
    let pool = ctx.pool();
    assert!(upper_bound >= g.max_node_weight());
    let mut labels: Vec<u32> = (0..n as u32).collect();
    // The size table is round scratch (labels escape, the table does
    // not), so it leases from the context workspace.
    let mut cluster_weight = ctx.workspace().caller().lease::<Vec<Weight>>(n);
    cluster_weight.extend_from_slice(g.node_weights());

    let mut rounds = 0usize;
    let mut converged = false;
    for round in 0..max_iterations {
        crate::util::cancel::checkpoint();
        let round_seed = rng.next_u64();
        let applied = synchronous_round(
            g,
            &mut labels,
            &mut cluster_weight,
            None,
            upper_bound,
            SyncMode::Clustering,
            pool,
            RoundScratch::Workspace(ctx.workspace()),
            round_seed,
        );
        debug_assert!(cluster_weight.iter().all(|&w| w <= upper_bound));
        rounds = round + 1;
        // Emitted on the driver thread, after the synchronous round's
        // barrier — deterministic for any pool size.
        trace::counter(
            "parallel_lpa_round",
            &[("round", round as i64), ("moved", applied as i64)],
        );
        if (applied as f64) < 0.05 * n as f64 {
            converged = true;
            break;
        }
    }
    let reason = if converged {
        crate::obs::quality::STOP_CONVERGED
    } else {
        crate::obs::quality::STOP_MAX_ITERATIONS
    };
    trace::counter(
        "parallel_lpa_done",
        &[("rounds", rounds as i64), ("reason", reason)],
    );

    Clustering::from_labels(g, labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators;
    use crate::graph::karate::karate_club;

    #[test]
    fn parallel_respects_bound() {
        let g = karate_club();
        for threads in [1usize, 2, 4] {
            let ctx = ExecutionCtx::new(threads);
            let mut rng = Rng::new(1);
            let c = parallel_sclap(&g, 6, 10, &ctx, &mut rng);
            assert!(c.respects_bound(6), "threads={threads}: {:?}", c.cluster_weights);
        }
    }

    #[test]
    fn parallel_finds_structure() {
        let mut rng = Rng::new(2);
        let g = generators::barabasi_albert(2000, 4, &mut rng);
        let ctx = ExecutionCtx::new(4);
        let c = parallel_sclap(&g, 50, 10, &ctx, &mut Rng::new(3));
        assert!(c.num_clusters < g.n() / 2, "nc={}", c.num_clusters);
        assert!(c.respects_bound(50));
    }

    #[test]
    fn labels_identical_across_pool_sizes() {
        // The tentpole invariant at the engine level: same seed, any
        // thread count, bit-identical labels. n=2000 spans several
        // SCORING_CHUNK chunks, so the parallel path is really exercised.
        let mut rng = Rng::new(4);
        let g = generators::rmat(11, 6000, 0.57, 0.19, 0.19, &mut rng);
        let run = |threads: usize| {
            let ctx = ExecutionCtx::new(threads);
            parallel_sclap(&g, 30, 5, &ctx, &mut Rng::new(7)).labels
        };
        let reference = run(1);
        for threads in [2usize, 3, 4, 8] {
            assert_eq!(reference, run(threads), "threads={threads} diverged");
        }
    }

    #[test]
    fn rerun_same_seed_identical() {
        let mut rng = Rng::new(5);
        let g = generators::barabasi_albert(1500, 3, &mut rng);
        let ctx = ExecutionCtx::new(4);
        let a = parallel_sclap(&g, 25, 5, &ctx, &mut Rng::new(9)).labels;
        let b = parallel_sclap(&g, 25, 5, &ctx, &mut Rng::new(9)).labels;
        assert_eq!(a, b);
    }

    #[test]
    fn reconcile_skips_ineligible() {
        let g = karate_club();
        let mut labels: Vec<u32> = (0..34).collect();
        let mut weights: Vec<Weight> = vec![1; 34];
        // Two proposals targeting cluster 0 with U=2: only one fits.
        let mut props = vec![
            Proposal { node: 5, target: 0, gain: 3 },
            Proposal { node: 6, target: 0, gain: 2 },
        ];
        let applied = reconcile_proposals(&g, &mut labels, &mut weights, 2, &mut props);
        assert_eq!(applied, 1);
        assert_eq!(labels[5], 0); // higher gain won
        assert_eq!(labels[6], 6);
        assert_eq!(weights[0], 2);
    }

    #[test]
    fn refinement_round_never_empties_blocks() {
        let g = karate_club();
        let k = 2usize;
        let mut labels: Vec<u32> = (0..34u32).map(|v| v % 2).collect();
        let mut weight = vec![0 as Weight; k];
        let mut count = vec![0u32; k];
        for &l in &labels {
            weight[l as usize] += 1;
            count[l as usize] += 1;
        }
        let pool = ThreadPool::new(2);
        let scratch = WorkerLocal::new(pool.threads(), || FastResetArray::new(k));
        for round in 0..5u64 {
            synchronous_round(
                &g,
                &mut labels,
                &mut weight,
                Some(&mut count),
                20,
                SyncMode::Refinement,
                &pool,
                RoundScratch::Local(&scratch),
                round,
            );
            assert!(weight.iter().all(|&w| w <= 20), "{weight:?}");
            assert!(count.iter().all(|&c| c >= 1), "block emptied: {count:?}");
        }
    }
}
