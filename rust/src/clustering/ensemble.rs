//! Ensemble / overlay clusterings (§4 "Ensemble Clusterings").
//!
//! Two nodes share a cluster in the overlay iff they share a cluster in
//! *every* input clustering. The paper computes the overlay iteratively:
//! fold clusterings pairwise, hashing `(overlay_id, current_id)` pairs to
//! fresh dense ids. We implement exactly that fold (the paper chose it
//! over ℓ-tuple hashing for simplicity; so do we).

use crate::graph::csr::Graph;
use crate::util::rng::Rng;
use std::collections::HashMap;

use super::label_propagation::{size_constrained_lpa, Clustering, LpaConfig};

/// Overlay of two label arrays: nodes together iff together in both.
pub fn overlay_pair(a: &[u32], b: &[u32]) -> Vec<u32> {
    assert_eq!(a.len(), b.len());
    let mut map: HashMap<(u32, u32), u32> = HashMap::new();
    let mut out = vec![0u32; a.len()];
    let mut next = 0u32;
    for v in 0..a.len() {
        let key = (a[v], b[v]);
        let id = *map.entry(key).or_insert_with(|| {
            let id = next;
            next += 1;
            id
        });
        out[v] = id;
    }
    out
}

/// Overlay of many clusterings by iterated pairwise folding (§4).
pub fn overlay_clustering(g: &Graph, inputs: &[Vec<u32>]) -> Clustering {
    assert!(!inputs.is_empty());
    let mut overlay = inputs[0].clone();
    for c in &inputs[1..] {
        overlay = overlay_pair(&overlay, c);
    }
    Clustering::from_labels(g, overlay)
}

/// Run `count` independent SCLaP clusterings and overlay them — the
/// ensemble coarsening used by the `…/E` configurations. Each run gets
/// an independent RNG stream; feasibility of every input implies
/// feasibility of the overlay (overlay clusters are subsets).
pub fn ensemble_sclap(
    g: &Graph,
    upper_bound: i64,
    config: &LpaConfig,
    count: usize,
    respect: Option<&[u32]>,
    rng: &mut Rng,
) -> Clustering {
    assert!(count >= 1);
    let runs: Vec<Vec<u32>> = (0..count)
        .map(|_| {
            let mut stream = rng.split();
            size_constrained_lpa(g, upper_bound, config, None, respect, &mut stream)
                .0
                .labels
        })
        .collect();
    overlay_clustering(g, &runs)
}

/// Paper §5: ensemble size by k — 18 below 16 blocks, 7 for 16–32, 3 above.
pub fn ensemble_size_for_k(k: usize) -> usize {
    if k < 16 {
        18
    } else if k <= 32 {
        7
    } else {
        3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::label_propagation::NodeOrdering;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::karate::karate_club;

    #[test]
    fn overlay_pair_intersects() {
        let a = vec![0, 0, 0, 1, 1, 1];
        let b = vec![0, 0, 1, 1, 2, 2];
        let o = overlay_pair(&a, &b);
        // groups: {0,1}, {2}, {3}, {4,5}
        assert_eq!(o[0], o[1]);
        assert_ne!(o[1], o[2]);
        assert_ne!(o[2], o[3]);
        assert_eq!(o[4], o[5]);
        assert_ne!(o[3], o[4]);
    }

    #[test]
    fn overlay_with_self_is_identity_partition() {
        let a = vec![5u32, 5, 7, 7, 9];
        let o = overlay_pair(&a, &a);
        assert_eq!(o[0], o[1]);
        assert_eq!(o[2], o[3]);
        assert_ne!(o[0], o[2]);
        assert_ne!(o[3], o[4]);
    }

    #[test]
    fn overlay_never_coarser_than_inputs() {
        let g = karate_club();
        let mut rng = Rng::new(3);
        let cfg = LpaConfig::clustering(5, NodeOrdering::Random);
        let a = size_constrained_lpa(&g, 10, &cfg, None, None, &mut rng).0;
        let b = size_constrained_lpa(&g, 10, &cfg, None, None, &mut rng).0;
        let o = overlay_clustering(&g, &[a.labels.clone(), b.labels.clone()]);
        assert!(o.num_clusters >= a.num_clusters.max(b.num_clusters));
        // refinement property: same overlay cluster ⇒ same cluster in both
        for u in 0..g.n() {
            for v in (u + 1)..g.n() {
                if o.labels[u] == o.labels[v] {
                    assert_eq!(a.labels[u], a.labels[v]);
                    assert_eq!(b.labels[u], b.labels[v]);
                }
            }
        }
    }

    #[test]
    fn ensemble_feasible_if_inputs_feasible() {
        let g = karate_club();
        let mut rng = Rng::new(5);
        let cfg = LpaConfig::clustering(8, NodeOrdering::Degree);
        let e = ensemble_sclap(&g, 6, &cfg, 5, None, &mut rng);
        assert!(e.respects_bound(6), "{:?}", e.cluster_weights);
    }

    #[test]
    fn ensemble_sizes_match_paper() {
        assert_eq!(ensemble_size_for_k(2), 18);
        assert_eq!(ensemble_size_for_k(8), 18);
        assert_eq!(ensemble_size_for_k(16), 7);
        assert_eq!(ensemble_size_for_k(32), 7);
        assert_eq!(ensemble_size_for_k(64), 3);
    }

    #[test]
    fn overlay_of_disagreeing_singletons() {
        let g = GraphBuilder::new(3).edge(0, 1).edge(1, 2).build();
        let a = vec![0, 0, 0];
        let b = vec![0, 1, 2];
        let o = overlay_clustering(&g, &[a, b]);
        assert_eq!(o.num_clusters, 3);
    }
}
