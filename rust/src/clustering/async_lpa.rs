//! Deterministic **parallel asynchronous** SCLaP via graph coloring —
//! the approach of the paper's companion work *Parallel Graph
//! Partitioning for Complex Networks* (arXiv 1404.4797), on the shared
//! [`ExecutionCtx`] pool.
//!
//! The sequential engine (`label_propagation::size_constrained_lpa`) is
//! *asynchronous*: each node immediately sees the moves of previously
//! visited nodes. That data dependence is what made it "the big
//! remaining scaling item" (ROADMAP): a naive parallelization races on
//! the label reads. The companion paper's fix is classic: a **greedy
//! graph coloring** partitions the nodes into independent sets; within
//! one color class no two nodes are adjacent, so every label a class
//! member reads belongs to a node *outside* the class and is stable
//! while the class is processed. Rounds then walk the color classes in
//! order, scoring each class in parallel with the **same move rule as
//! the sequential engine** (strongest eligible neighboring cluster,
//! ties broken by reservoir sampling, size bound `U` respected) and
//! applying the proposed moves sequentially in class order against the
//! live cluster-size table — so the size constraint holds *exactly*
//! after every class, not just in expectation.
//!
//! # Determinism
//!
//! The schedule is a pure function of the input: the coloring follows
//! the (seeded) node order; class member lists inherit that order; each
//! scoring chunk is a fixed-size slice of a class with an RNG stream
//! derived from `(round seed, class, chunk)` via
//! [`exec::derive_seed`]; and the apply pass walks proposals in class
//! order. The executing pool size is unobservable — `threads ∈ {1,2,4}`
//! produce byte-identical labels (enforced by `rust/tests/properties.rs`
//! and, end-to-end through the coarsening path, by
//! `rust/tests/determinism.rs`).
//!
//! Like the synchronous engine (`clustering::parallel_lpa`), this is a
//! *different algorithm* from the sequential asynchronous engine — the
//! eligibility snapshot is per-class rather than per-node — so it is
//! opt-in via `PartitionConfig::parallel_coarsening`
//! (`crate::partitioning::config`), selected by configuration, never by
//! thread count.

use crate::clustering::label_propagation::{build_order_into, Clustering, LpaConfig, LpaMode};
use crate::graph::csr::{Graph, NodeId, Weight};
use crate::obs::trace;
use crate::util::exec::{derive_seed, ExecutionCtx};
use crate::util::fast_reset::FastResetArray;
use crate::util::rng::Rng;

/// Class members per scoring chunk. Fixed (never derived from the
/// thread count) so the decomposition — and with it every per-chunk RNG
/// stream — is part of the deterministic logical schedule.
pub const COLOR_CHUNK: usize = 256;

/// Greedy coloring in visit order: each node takes the smallest color
/// not used by an already-colored neighbor. Returns the color classes,
/// each member list in visit order. The number of classes is at most
/// `max_degree + 1`.
pub fn greedy_color_classes(g: &Graph, order: &[NodeId]) -> Vec<Vec<NodeId>> {
    let n = g.n();
    let mut color = vec![u32::MAX; n];
    let mut classes: Vec<Vec<NodeId>> = Vec::new();
    // mark[c] == stamp ⇔ color c is taken by a neighbor of the current
    // node (fast-reset by stamping; no clearing between nodes).
    let mut mark: Vec<u32> = Vec::new();
    for (visit, &v) in order.iter().enumerate() {
        let stamp = visit as u32 + 1;
        for &u in g.adjacent(v) {
            let cu = color[u as usize];
            if cu != u32::MAX {
                let cu = cu as usize;
                if cu >= mark.len() {
                    mark.resize(cu + 1, 0);
                }
                mark[cu] = stamp;
            }
        }
        let mut c = 0usize;
        while c < mark.len() && mark[c] == stamp {
            c += 1;
        }
        color[v as usize] = c as u32;
        if c == classes.len() {
            classes.push(Vec::new());
        }
        classes[c].push(v);
    }
    classes
}

/// Score one slice of a color class against the current labels and the
/// class-start cluster-weight snapshot, with the sequential engine's
/// move rule. Pure function of its arguments — safe on pool workers.
#[allow(clippy::too_many_arguments)]
fn score_members(
    g: &Graph,
    labels: &[u32],
    cluster_weight: &[Weight],
    upper_bound: Weight,
    members: &[NodeId],
    seed: u64,
    respect: Option<&[u32]>,
    conn: &mut FastResetArray<i64>,
) -> Vec<(NodeId, u32)> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for &v in members {
        let cur = labels[v as usize];
        let vw = g.node_weight(v);
        let adj = g.adjacent(v);
        if adj.is_empty() {
            continue;
        }
        let weights = g.adjacent_weights(v);
        conn.clear();
        match respect {
            // V-cycle restriction (§B.1): only clusters in the same block.
            Some(blocks) => {
                let bv = blocks[v as usize];
                for (&u, &w) in adj.iter().zip(weights) {
                    if blocks[u as usize] == bv {
                        conn.accumulate(labels[u as usize] as usize, w);
                    }
                }
            }
            None => {
                for (&u, &w) in adj.iter().zip(weights) {
                    conn.accumulate(labels[u as usize] as usize, w);
                }
            }
        }
        // Same scan as the sequential `try_move` (clustering mode):
        // staying is an option with the connection to `cur`; candidates
        // must fit under the bound; equal-strength candidates are chosen
        // by reservoir sampling (zero-gain tie moves allowed).
        let mut best_conn: i64 = conn.get(cur as usize);
        let mut best: u32 = cur;
        let mut ties: u32 = 1;
        for &c in conn.touched() {
            let c32 = c as u32;
            if c32 == cur {
                continue;
            }
            if cluster_weight[c] + vw > upper_bound {
                continue;
            }
            let score = conn.value_of_touched(c);
            if score > best_conn {
                best_conn = score;
                best = c32;
                ties = 1;
            } else if score == best_conn {
                ties += 1;
                if rng.below(ties as usize) == 0 {
                    best = c32;
                }
            }
        }
        if best != cur {
            out.push((v, best));
        }
    }
    out
}

/// Parallel asynchronous size-constrained LPA (clustering mode,
/// singleton start) — see the module docs. Returns the dense clustering
/// and the number of rounds executed; output is byte-identical for
/// every pool size given the same `rng` stream.
pub fn parallel_async_sclap(
    g: &Graph,
    upper_bound: Weight,
    config: &LpaConfig,
    respect: Option<&[u32]>,
    ctx: &ExecutionCtx,
    rng: &mut Rng,
) -> (Clustering, usize) {
    let n = g.n();
    assert_eq!(
        config.mode,
        LpaMode::Clustering,
        "parallel async SCLaP is a coarsening engine"
    );
    assert!(
        upper_bound >= g.max_node_weight(),
        "U={} below max node weight {}",
        upper_bound,
        g.max_node_weight()
    );
    if let Some(r) = respect {
        assert_eq!(r.len(), n);
    }

    let ws = ctx.workspace();
    let mut labels: Vec<u32> = (0..n as u32).collect();
    // The size table and visit order are round scratch (labels escape
    // into the clustering, these do not) — leased from the workspace.
    let mut cluster_weight = ws.caller().lease::<Vec<Weight>>(n);
    cluster_weight.extend_from_slice(g.node_weights());
    let mut order = ws.caller().lease::<Vec<NodeId>>(n);
    build_order_into(g, config.ordering, rng, &mut order);
    // The coloring depends only on the graph and the order, so it is
    // computed once and reused across rounds.
    let classes = greedy_color_classes(g, &order);
    let pool = ctx.pool();

    let mut rounds = 0usize;
    let mut converged = false;
    while rounds < config.max_iterations {
        crate::util::cancel::checkpoint();
        rounds += 1;
        let round_seed = rng.next_u64();
        let mut moved = 0usize;
        for (ci, class) in classes.iter().enumerate() {
            let num_chunks = class.len().div_ceil(COLOR_CHUNK);
            let proposals: Vec<Vec<(NodeId, u32)>> = {
                let labels_ref: &[u32] = &labels;
                let weight_ref: &[Weight] = &cluster_weight;
                pool.map_indexed(num_chunks, |worker, chunk| {
                    let lo = chunk * COLOR_CHUNK;
                    let hi = (lo + COLOR_CHUNK).min(class.len());
                    // Leased from the executing worker's arena shard: in
                    // the steady state the shard hands back the same
                    // buffer every chunk, so rounds allocate nothing.
                    let mut conn = ws.worker(worker).lease::<FastResetArray<i64>>(n.max(1));
                    score_members(
                        g,
                        labels_ref,
                        weight_ref,
                        upper_bound,
                        &class[lo..hi],
                        derive_seed(round_seed, ((ci as u64) << 32) ^ chunk as u64),
                        respect,
                        &mut conn,
                    )
                })
            };
            // Apply in class order against the live size table: a target
            // that filled up since the class-start snapshot is skipped,
            // so the bound holds exactly after every class.
            for (v, target) in proposals.into_iter().flatten() {
                let vw = g.node_weight(v);
                let cur = labels[v as usize];
                if cluster_weight[target as usize] + vw > upper_bound {
                    continue;
                }
                cluster_weight[cur as usize] -= vw;
                cluster_weight[target as usize] += vw;
                labels[v as usize] = target;
                moved += 1;
            }
        }
        debug_assert!(cluster_weight.iter().all(|&w| w <= upper_bound));
        // Driver-thread emission after the class barrier: deterministic
        // for any pool size (the apply order above already is).
        trace::counter(
            "async_lpa_round",
            &[("round", rounds as i64), ("moved", moved as i64)],
        );
        if (moved as f64) < config.convergence_fraction * n as f64 {
            converged = true;
            break;
        }
    }
    let reason = if converged {
        crate::obs::quality::STOP_CONVERGED
    } else {
        crate::obs::quality::STOP_MAX_ITERATIONS
    };
    trace::counter(
        "async_lpa_done",
        &[("rounds", rounds as i64), ("reason", reason)],
    );

    (Clustering::from_labels(g, labels), rounds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::label_propagation::NodeOrdering;
    use crate::generators;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::karate::karate_club;

    fn is_proper_coloring(g: &Graph, classes: &[Vec<NodeId>]) -> bool {
        let mut color = vec![u32::MAX; g.n()];
        for (c, class) in classes.iter().enumerate() {
            for &v in class {
                color[v as usize] = c as u32;
            }
        }
        color.iter().all(|&c| c != u32::MAX)
            && g.edges()
                .all(|(u, v, _)| color[u as usize] != color[v as usize])
    }

    #[test]
    fn coloring_is_proper_and_complete() {
        let mut rng = Rng::new(1);
        for g in [
            karate_club(),
            generators::barabasi_albert(800, 4, &mut rng),
            generators::grid2d(17, 23),
        ] {
            let order: Vec<NodeId> = g.nodes().collect();
            let classes = greedy_color_classes(&g, &order);
            assert!(is_proper_coloring(&g, &classes), "improper coloring");
            assert!(classes.len() <= g.max_degree() + 1);
            assert_eq!(classes.iter().map(|c| c.len()).sum::<usize>(), g.n());
        }
    }

    #[test]
    fn grid_two_colors() {
        // A bipartite graph colored in natural order needs 2 colors.
        let g = generators::grid2d(8, 8);
        let order: Vec<NodeId> = g.nodes().collect();
        let classes = greedy_color_classes(&g, &order);
        assert_eq!(classes.len(), 2);
    }

    #[test]
    fn finds_clique_structure() {
        // Two K4s joined by one edge — same sanity case as the
        // sequential engine's test.
        let mut b = GraphBuilder::new(8);
        for base in [0u32, 4] {
            for i in 0..4 {
                for j in (i + 1)..4 {
                    b.add_edge(base + i, base + j, 1);
                }
            }
        }
        b.add_edge(3, 4, 1);
        let g = b.build();
        let ctx = ExecutionCtx::sequential();
        let cfg = LpaConfig::clustering(10, NodeOrdering::Degree);
        let (c, _) = parallel_async_sclap(&g, 4, &cfg, None, &ctx, &mut Rng::new(3));
        assert_eq!(c.num_clusters, 2);
        assert!((1..4).all(|i| c.labels[i] == c.labels[0]));
        assert!((5..8).all(|i| c.labels[i] == c.labels[4]));
        assert_eq!(c.cut(&g), 1);
    }

    #[test]
    fn respects_bound_for_many_seeds() {
        let mut rng = Rng::new(5);
        let g = generators::barabasi_albert(600, 3, &mut rng);
        let ctx = ExecutionCtx::new(4);
        let cfg = LpaConfig::clustering(5, NodeOrdering::Degree);
        for seed in 0..6 {
            let (c, _) =
                parallel_async_sclap(&g, 20, &cfg, None, &ctx, &mut Rng::new(seed));
            assert!(c.respects_bound(20), "seed {seed}: {:?}", c.cluster_weights);
            assert!(c.num_clusters < g.n(), "no clustering happened");
        }
    }

    #[test]
    fn labels_identical_across_pool_sizes() {
        // The tentpole invariant: same seed, any thread count,
        // bit-identical labels. n spans several COLOR_CHUNK chunks in
        // the large color classes, so the parallel path is exercised.
        let mut rng = Rng::new(7);
        let g = generators::rmat(11, 6000, 0.57, 0.19, 0.19, &mut rng);
        let cfg = LpaConfig::clustering(5, NodeOrdering::Degree);
        let run = |threads: usize| {
            let ctx = ExecutionCtx::new(threads);
            parallel_async_sclap(&g, 30, &cfg, None, &ctx, &mut Rng::new(11))
                .0
                .labels
        };
        let reference = run(1);
        for threads in [2usize, 3, 4, 8] {
            assert_eq!(reference, run(threads), "threads={threads} diverged");
        }
    }

    #[test]
    fn respect_partition_blocks_cross_moves() {
        let mut rng = Rng::new(9);
        let g = generators::barabasi_albert(400, 3, &mut rng);
        let blocks: Vec<u32> = (0..g.n() as u32).map(|v| v % 2).collect();
        let ctx = ExecutionCtx::new(2);
        let cfg = LpaConfig::clustering(5, NodeOrdering::Degree);
        let (c, _) =
            parallel_async_sclap(&g, 30, &cfg, Some(&blocks), &ctx, &mut Rng::new(13));
        for (u, v, _) in g.edges() {
            if blocks[u as usize] != blocks[v as usize] {
                assert_ne!(
                    c.labels[u as usize], c.labels[v as usize],
                    "cluster crossed the block boundary on edge ({u},{v})"
                );
            }
        }
    }

    #[test]
    fn isolated_nodes_stay_put() {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 1);
        let g = b.build();
        let ctx = ExecutionCtx::sequential();
        let cfg = LpaConfig::clustering(5, NodeOrdering::Degree);
        let (c, _) = parallel_async_sclap(&g, 4, &cfg, None, &ctx, &mut Rng::new(1));
        assert!(c.num_clusters >= 3);
    }
}
