//! Size-constrained label propagation (SCLaP) — §3.1 of the paper.
//!
//! One engine serves both roles the paper gives it:
//!
//! - **Coarsening** ([`LpaMode::Clustering`]): every node starts in its
//!   own cluster; nodes move to the *eligible* neighboring cluster with
//!   the strongest connection (`U = max(max_v c(v), L_max/(f·k))`).
//!   The result is contracted into the next-coarser graph.
//! - **Local search** ([`LpaMode::Refinement`]): labels start as the
//!   current partition blocks and `U = L_max`. If a node's own block is
//!   overloaded it *must* consider only other blocks (the paper's
//!   overloaded-block rule) so balance strictly improves.
//!
//! Extensions from §4 are all here: node orderings (random / increasing
//! degree / weighted degree), the active-nodes rounds (two FIFO queues +
//! two bit vectors, §B.2), and partition-respecting moves for V-cycles
//! (§B.1: each cluster stays inside one block of the input partition so
//! cut edges are never contracted).

use crate::graph::csr::{Graph, NodeId, Weight};
use crate::obs::trace;
use crate::partitioning::workspace::VcycleWorkspace;
use crate::util::arena::scratch;
use crate::util::fast_reset::{BitVec, FastResetArray};
use crate::util::rng::Rng;
use std::collections::VecDeque;

/// Node traversal order for LPA rounds (§4 "Node Ordering").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeOrdering {
    /// Random permutation per run (the original LPA; configs `*R`).
    Random,
    /// Increasing node degree — small-degree nodes settle first so a
    /// meaningful cluster structure exists when hubs choose (default).
    Degree,
    /// Increasing weighted degree (paper: comparable to `Degree`).
    WeightedDegree,
}

/// Which of the paper's two roles the engine plays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LpaMode {
    /// Coarsening clustering: singletons → size-constrained clusters.
    Clustering,
    /// Local search on an existing partition: the overloaded-block rule
    /// applies and blocks may not be emptied.
    Refinement,
}

/// Tuning knobs for one SCLaP invocation.
#[derive(Debug, Clone)]
pub struct LpaConfig {
    /// Maximum rounds ℓ (paper default 10; 3 for huge graphs).
    pub max_iterations: usize,
    pub ordering: NodeOrdering,
    /// Active-nodes optimization (§4 / §B.2). Always used in refinement.
    pub active_nodes: bool,
    /// Stop when fewer than this fraction of nodes moved in a round
    /// (paper: five percent).
    pub convergence_fraction: f64,
    pub mode: LpaMode,
}

impl Default for LpaConfig {
    fn default() -> Self {
        LpaConfig {
            max_iterations: 10,
            ordering: NodeOrdering::Degree,
            active_nodes: false,
            convergence_fraction: 0.05,
            mode: LpaMode::Clustering,
        }
    }
}

impl LpaConfig {
    pub fn clustering(max_iterations: usize, ordering: NodeOrdering) -> Self {
        LpaConfig {
            max_iterations,
            ordering,
            ..Default::default()
        }
    }

    pub fn refinement(max_iterations: usize) -> Self {
        LpaConfig {
            max_iterations,
            ordering: NodeOrdering::Degree,
            active_nodes: true, // paper: always used during uncoarsening
            convergence_fraction: 0.05,
            mode: LpaMode::Refinement,
        }
    }
}

/// A clustering/labelling of the nodes with per-cluster weights.
#[derive(Debug, Clone)]
pub struct Clustering {
    /// Cluster label per node, dense in `0..num_clusters`.
    pub labels: Vec<u32>,
    pub num_clusters: usize,
    /// Total node weight per cluster.
    pub cluster_weights: Vec<Weight>,
}

impl Clustering {
    /// Build from an arbitrary (possibly sparse) label array.
    pub fn from_labels(g: &Graph, labels: Vec<u32>) -> Self {
        let mut c = Clustering {
            labels,
            num_clusters: 0,
            cluster_weights: Vec::new(),
        };
        c.make_dense(g);
        c
    }

    /// Relabel to dense ids `0..num_clusters` and recompute weights.
    fn make_dense(&mut self, g: &Graph) {
        let mut remap: Vec<u32> = vec![u32::MAX; self.labels.len().max(1)];
        let mut next = 0u32;
        for l in self.labels.iter_mut() {
            let slot = *l as usize;
            if remap[slot] == u32::MAX {
                remap[slot] = next;
                next += 1;
            }
            *l = remap[slot];
        }
        self.num_clusters = next as usize;
        let mut weights = vec![0 as Weight; self.num_clusters];
        for v in g.nodes() {
            weights[self.labels[v as usize] as usize] += g.node_weight(v);
        }
        self.cluster_weights = weights;
    }

    /// Number of edges (by weight) cut between clusters.
    pub fn cut(&self, g: &Graph) -> Weight {
        g.edges()
            .filter(|&(u, v, _)| self.labels[u as usize] != self.labels[v as usize])
            .map(|(_, _, w)| w)
            .sum()
    }

    /// Check the size constraint.
    pub fn respects_bound(&self, bound: Weight) -> bool {
        self.cluster_weights.iter().all(|&w| w <= bound)
    }
}

/// Run size-constrained label propagation.
///
/// * `upper_bound` — `U`: no cluster's node weight may exceed it. Must be
///   at least the maximum node weight (the caller applies the paper's
///   `U := max(max_v c(v), W)` rule; we assert it).
/// * `initial` — starting labels (`None` ⇒ singletons, only valid in
///   clustering mode; refinement mode requires the current partition).
/// * `respect` — optional block array for V-cycles (§B.1): a node may
///   only join clusters inside its own block, so cut edges survive
///   contraction.
///
/// Returns the dense clustering and the number of rounds executed.
pub fn size_constrained_lpa(
    g: &Graph,
    upper_bound: Weight,
    config: &LpaConfig,
    initial: Option<Vec<u32>>,
    respect: Option<&[u32]>,
    rng: &mut Rng,
) -> (Clustering, usize) {
    size_constrained_lpa_ws(g, upper_bound, config, initial, respect, None, rng)
}

/// [`size_constrained_lpa`] with round scratch (cluster tables, node
/// order, connection accumulator, active-node queues/bit vectors)
/// leased from a workspace when one is supplied. Bit-identical output
/// either way — leases hand out cleared buffers, so only allocation
/// traffic changes (the multilevel driver's steady-state levels stop
/// allocating).
#[allow(clippy::too_many_arguments)]
pub fn size_constrained_lpa_ws(
    g: &Graph,
    upper_bound: Weight,
    config: &LpaConfig,
    initial: Option<Vec<u32>>,
    respect: Option<&[u32]>,
    ws: Option<&VcycleWorkspace>,
    rng: &mut Rng,
) -> (Clustering, usize) {
    let n = g.n();
    assert!(
        upper_bound >= g.max_node_weight(),
        "U={} below max node weight {}",
        upper_bound,
        g.max_node_weight()
    );
    if let Some(r) = respect {
        assert_eq!(r.len(), n);
    }
    let arena = ws.map(|w| w.caller());

    let mut labels: Vec<u32> = match initial {
        Some(init) => {
            assert_eq!(init.len(), n);
            init
        }
        None => {
            assert_eq!(config.mode, LpaMode::Clustering);
            (0..n as u32).collect()
        }
    };

    // Cluster weight table, indexed by (sparse) label. Pure working
    // state — `make_dense` recomputes dense weights at the end — so it
    // leases.
    let max_label = labels.iter().copied().max().unwrap_or(0) as usize;
    let table = (max_label + 1).max(n);
    let mut cw_l = arena.map(|a| a.lease::<Vec<Weight>>(table));
    let mut cw_o = Vec::new();
    let cluster_weight = scratch(&mut cw_l, &mut cw_o);
    cluster_weight.resize(table, 0);
    let mut cc_l = arena.map(|a| a.lease::<Vec<u32>>(table));
    let mut cc_o = Vec::new();
    let cluster_count = scratch(&mut cc_l, &mut cc_o);
    cluster_count.resize(table, 0);
    for v in g.nodes() {
        cluster_weight[labels[v as usize] as usize] += g.node_weight(v);
        cluster_count[labels[v as usize] as usize] += 1;
    }
    debug_assert!(
        config.mode == LpaMode::Refinement
            || cluster_weight.iter().all(|&w| w <= upper_bound)
    );

    let mut order_l = arena.map(|a| a.lease::<Vec<NodeId>>(n));
    let mut order_o = Vec::new();
    let order = scratch(&mut order_l, &mut order_o);
    build_order_into(g, config.ordering, rng, order);
    let mut conn_l = arena.map(|a| a.lease::<FastResetArray<i64>>(table));
    let mut conn_o = FastResetArray::new(0);
    let conn = scratch(&mut conn_l, &mut conn_o);
    conn.ensure_capacity(table);
    let mut rounds = 0usize;
    let mut converged = false;

    if config.active_nodes {
        // §B.2: two FIFO queues + two bit vectors swapped per round.
        let mut cur_l = arena.map(|a| a.lease::<VecDeque<NodeId>>(n));
        let mut cur_o = VecDeque::new();
        let current = scratch(&mut cur_l, &mut cur_o);
        current.extend(order.iter().copied());
        let mut next_l = arena.map(|a| a.lease::<VecDeque<NodeId>>(n));
        let mut next_o = VecDeque::new();
        let next = scratch(&mut next_l, &mut next_o);
        let mut inc_l = arena.map(|a| a.lease::<BitVec>(n));
        let mut inc_o = BitVec::new(0);
        let in_current = scratch(&mut inc_l, &mut inc_o);
        in_current.reset_len(n);
        let mut inn_l = arena.map(|a| a.lease::<BitVec>(n));
        let mut inn_o = BitVec::new(0);
        let in_next = scratch(&mut inn_l, &mut inn_o);
        in_next.reset_len(n);
        for &v in order.iter() {
            in_current.set(v as usize, true);
        }
        while rounds < config.max_iterations && !current.is_empty() {
            crate::util::cancel::checkpoint();
            rounds += 1;
            let mut changed = 0usize;
            while let Some(v) = current.pop_front() {
                in_current.set(v as usize, false);
                let moved = try_move(
                    g,
                    v,
                    &mut labels,
                    cluster_weight,
                    cluster_count,
                    upper_bound,
                    config.mode,
                    respect,
                    conn,
                    rng,
                );
                if moved {
                    changed += 1;
                    for &u in g.adjacent(v) {
                        if !in_next.get(u as usize) {
                            in_next.set(u as usize, true);
                            next.push_back(u);
                        }
                    }
                    // The moved node itself may improve further next round.
                    if !in_next.get(v as usize) {
                        in_next.set(v as usize, true);
                        next.push_back(v);
                    }
                }
            }
            trace::counter(
                "lpa_round",
                &[("round", rounds as i64), ("moved", changed as i64)],
            );
            std::mem::swap(current, next);
            std::mem::swap(in_current, in_next);
            if (changed as f64) < config.convergence_fraction * n as f64 {
                converged = true;
                break;
            }
        }
        let reason = if converged {
            crate::obs::quality::STOP_CONVERGED
        } else if rounds < config.max_iterations {
            crate::obs::quality::STOP_EXHAUSTED
        } else {
            crate::obs::quality::STOP_MAX_ITERATIONS
        };
        trace::counter("lpa_done", &[("rounds", rounds as i64), ("reason", reason)]);
    } else {
        while rounds < config.max_iterations {
            crate::util::cancel::checkpoint();
            rounds += 1;
            let mut changed = 0usize;
            for i in 0..order.len() {
                let v = order[i];
                if try_move(
                    g,
                    v,
                    &mut labels,
                    cluster_weight,
                    cluster_count,
                    upper_bound,
                    config.mode,
                    respect,
                    conn,
                    rng,
                ) {
                    changed += 1;
                }
            }
            trace::counter(
                "lpa_round",
                &[("round", rounds as i64), ("moved", changed as i64)],
            );
            if (changed as f64) < config.convergence_fraction * n as f64 {
                converged = true;
                break;
            }
            if config.ordering == NodeOrdering::Random {
                rng.shuffle(&mut order[..]);
            }
        }
        let reason = if converged {
            crate::obs::quality::STOP_CONVERGED
        } else {
            crate::obs::quality::STOP_MAX_ITERATIONS
        };
        trace::counter("lpa_done", &[("rounds", rounds as i64), ("reason", reason)]);
    }

    let mut clustering = Clustering {
        labels,
        num_clusters: 0,
        cluster_weights: Vec::new(),
    };
    clustering.make_dense(g);
    (clustering, rounds)
}

/// Visit one node; move it to the strongest eligible cluster.
/// Returns true if the label changed.
#[allow(clippy::too_many_arguments)]
#[inline]
fn try_move(
    g: &Graph,
    v: NodeId,
    labels: &mut [u32],
    cluster_weight: &mut [Weight],
    cluster_count: &mut [u32],
    upper_bound: Weight,
    mode: LpaMode,
    respect: Option<&[u32]>,
    conn: &mut FastResetArray<i64>,
    rng: &mut Rng,
) -> bool {
    let cur = labels[v as usize];
    let vw = g.node_weight(v);
    let adj = g.adjacent(v);
    if adj.is_empty() {
        return false;
    }
    let weights = g.adjacent_weights(v);

    conn.clear();
    match respect {
        // V-cycle restriction (§B.1): only clusters in the same block.
        Some(blocks) => {
            let bv = blocks[v as usize];
            for (&u, &w) in adj.iter().zip(weights) {
                if blocks[u as usize] == bv {
                    conn.accumulate(labels[u as usize] as usize, w);
                }
            }
        }
        // Hot path: one accumulate per arc, no per-arc branch or bounds
        // check. SAFETY: CSR validity gives u < n, labels.len() == n and
        // every label < cluster_weight.len() == conn.capacity().
        None => unsafe {
            for (&u, &w) in adj.iter().zip(weights) {
                let label = *labels.get_unchecked(u as usize) as usize;
                conn.accumulate_unchecked(label, w);
            }
        },
    }

    let overloaded = mode == LpaMode::Refinement && cluster_weight[cur as usize] > upper_bound;
    // Refinement must not empty a block (k is fixed).
    let would_empty = mode == LpaMode::Refinement && cluster_count[cur as usize] <= 1;
    if would_empty {
        return false;
    }

    // Scan neighboring clusters for the strongest eligible one.
    // Ties broken uniformly at random (reservoir over the argmax set).
    let mut best_conn: i64 = if overloaded {
        // Overloaded-block rule: choose among *other* blocks regardless
        // of how strong the connection to the own block is.
        i64::MIN
    } else {
        // Staying is always an option with the connection to `cur`.
        conn.get(cur as usize)
    };
    let mut best: u32 = cur;
    let mut ties: u32 = 1;
    for &c in conn.touched() {
        let c32 = c as u32;
        if c32 == cur {
            continue;
        }
        // Eligibility: target must not become overloaded (its own bound).
        if cluster_weight[c] + vw > upper_bound {
            continue;
        }
        let score = conn.value_of_touched(c);
        if score > best_conn {
            best_conn = score;
            best = c32;
            ties = 1;
        } else if score == best_conn && best_conn > i64::MIN {
            // Reservoir sampling over equally-strong candidates.
            ties += 1;
            if rng.below(ties as usize) == 0 {
                best = c32;
            }
        }
    }

    if best == cur {
        return false;
    }
    labels[v as usize] = best;
    cluster_weight[cur as usize] -= vw;
    cluster_weight[best as usize] += vw;
    cluster_count[cur as usize] -= 1;
    cluster_count[best as usize] += 1;
    true
}

/// Build the node visit order for round one into a caller-provided
/// (typically leased) buffer. Shared with the parallel asynchronous
/// engine, `clustering::async_lpa`.
pub(crate) fn build_order_into(
    g: &Graph,
    ordering: NodeOrdering,
    rng: &mut Rng,
    order: &mut Vec<NodeId>,
) {
    order.clear();
    order.extend(g.nodes());
    match ordering {
        NodeOrdering::Random => rng.shuffle(order),
        NodeOrdering::Degree => {
            // Shuffle first so equal-degree nodes appear in random order,
            // then counting-sort by degree (stable, O(n + maxdeg) — a
            // comparison sort here costs ~15% of a 3-round run, §Perf
            // iteration 2).
            rng.shuffle(order);
            counting_sort_by(order, g.max_degree(), |v| g.degree(v));
        }
        NodeOrdering::WeightedDegree => {
            rng.shuffle(order);
            let max_wd = g
                .nodes()
                .map(|v| g.weighted_degree(v))
                .max()
                .unwrap_or(0)
                .max(0) as usize;
            if max_wd <= 4 * g.n() {
                counting_sort_by(order, max_wd, |v| g.weighted_degree(v) as usize);
            } else {
                order.sort_by_key(|&v| g.weighted_degree(v));
            }
        }
    }
}

/// Stable counting sort of `order` by `key(v) ∈ [0, max_key]`.
fn counting_sort_by<F: Fn(NodeId) -> usize>(order: &mut Vec<NodeId>, max_key: usize, key: F) {
    let mut counts = vec![0usize; max_key + 2];
    for &v in order.iter() {
        counts[key(v) + 1] += 1;
    }
    for i in 0..max_key + 1 {
        counts[i + 1] += counts[i];
    }
    let mut out = vec![0 as NodeId; order.len()];
    for &v in order.iter() {
        let k = key(v);
        out[counts[k]] = v;
        counts[k] += 1;
    }
    *order = out;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::karate::karate_club;

    fn two_cliques() -> Graph {
        // Two K4s joined by one edge: the obvious clustering is the cliques.
        let mut b = GraphBuilder::new(8);
        for base in [0u32, 4] {
            for i in 0..4 {
                for j in (i + 1)..4 {
                    b.add_edge(base + i, base + j, 1);
                }
            }
        }
        b.add_edge(3, 4, 1);
        b.build()
    }

    #[test]
    fn finds_clique_structure() {
        let g = two_cliques();
        let mut rng = Rng::new(1);
        let (c, _) = size_constrained_lpa(
            &g,
            4,
            &LpaConfig::clustering(10, NodeOrdering::Degree),
            None,
            None,
            &mut rng,
        );
        assert_eq!(c.num_clusters, 2);
        // all of clique 1 in one cluster
        assert!((1..4).all(|i| c.labels[i] == c.labels[0]));
        assert!((5..8).all(|i| c.labels[i] == c.labels[4]));
        assert_ne!(c.labels[0], c.labels[4]);
        assert_eq!(c.cut(&g), 1);
    }

    #[test]
    fn respects_size_constraint_tight() {
        let g = two_cliques();
        for seed in 0..10 {
            let mut rng = Rng::new(seed);
            let (c, _) = size_constrained_lpa(
                &g,
                2,
                &LpaConfig::clustering(10, NodeOrdering::Random),
                None,
                None,
                &mut rng,
            );
            assert!(c.respects_bound(2), "seed {seed}: {:?}", c.cluster_weights);
        }
    }

    #[test]
    fn bound_one_keeps_singletons() {
        let g = two_cliques();
        let mut rng = Rng::new(3);
        let (c, _) = size_constrained_lpa(
            &g,
            1,
            &LpaConfig::default(),
            None,
            None,
            &mut rng,
        );
        assert_eq!(c.num_clusters, 8);
        assert!(c.respects_bound(1));
    }

    #[test]
    #[should_panic(expected = "below max node weight")]
    fn bound_below_max_node_weight_panics() {
        let g = GraphBuilder::new(2)
            .node_weights(vec![5, 1])
            .edge(0, 1)
            .build();
        let mut rng = Rng::new(0);
        let _ = size_constrained_lpa(&g, 2, &LpaConfig::default(), None, None, &mut rng);
    }

    #[test]
    fn weighted_nodes_respect_bound() {
        let mut b = GraphBuilder::new(6);
        for i in 0..6u32 {
            for j in (i + 1)..6 {
                b.add_edge(i, j, 1);
            }
        }
        b.set_node_weight(0, 3);
        b.set_node_weight(1, 3);
        let g = b.build();
        for seed in 0..8 {
            let mut rng = Rng::new(seed);
            let (c, _) = size_constrained_lpa(
                &g,
                4,
                &LpaConfig::clustering(10, NodeOrdering::Random),
                None,
                None,
                &mut rng,
            );
            assert!(c.respects_bound(4), "{:?}", c.cluster_weights);
        }
    }

    #[test]
    fn karate_clusters_reasonably() {
        let g = karate_club();
        let mut rng = Rng::new(7);
        let (c, _) = size_constrained_lpa(
            &g,
            10,
            &LpaConfig::clustering(10, NodeOrdering::Degree),
            None,
            None,
            &mut rng,
        );
        assert!(c.num_clusters >= 4, "nc={}", c.num_clusters);
        assert!(c.respects_bound(10));
        // clustering should beat random: cut below total edges
        assert!(c.cut(&g) < 78);
    }

    #[test]
    fn active_nodes_matches_constraint_and_quality() {
        let mut rng = Rng::new(11);
        let g = generators::rmat(10, 4000, 0.57, 0.19, 0.19, &mut rng);
        let mut cfg = LpaConfig::clustering(10, NodeOrdering::Degree);
        let (c1, _) = size_constrained_lpa(&g, 40, &cfg, None, None, &mut Rng::new(1));
        cfg.active_nodes = true;
        let (c2, _) = size_constrained_lpa(&g, 40, &cfg, None, None, &mut Rng::new(1));
        assert!(c1.respects_bound(40));
        assert!(c2.respects_bound(40));
        // both should find substantial structure
        assert!(c1.num_clusters < g.n());
        assert!(c2.num_clusters < g.n());
    }

    #[test]
    fn refinement_reduces_cut() {
        let g = two_cliques();
        // bad initial partition: split across the cliques
        let initial = vec![0, 1, 0, 1, 0, 1, 0, 1];
        let before: Weight = g
            .edges()
            .filter(|&(u, v, _)| initial[u as usize] != initial[v as usize])
            .map(|(_, _, w)| w)
            .sum();
        let mut rng = Rng::new(5);
        // U = 5 gives one unit of slack (with U = 4 and perfectly
        // balanced blocks, *no* single move is eligible — also verified
        // in `refinement_fully_balanced_is_frozen`).
        let (c, _) = size_constrained_lpa(
            &g,
            5,
            &LpaConfig::refinement(10),
            Some(initial),
            None,
            &mut rng,
        );
        assert!(c.cut(&g) < before, "cut {} !< {before}", c.cut(&g));
        // still exactly two blocks (refinement never empties)
        assert_eq!(c.num_clusters, 2);
        assert!(c.respects_bound(5));
    }

    #[test]
    fn refinement_fully_balanced_is_frozen() {
        // With U equal to the exact block weight there is no slack: no
        // single move is eligible, so the partition must not change.
        let g = two_cliques();
        let initial = vec![0u32, 1, 0, 1, 0, 1, 0, 1];
        let mut rng = Rng::new(5);
        let (c, _) = size_constrained_lpa(
            &g,
            4,
            &LpaConfig::refinement(10),
            Some(initial.clone()),
            None,
            &mut rng,
        );
        // labels may be renamed by densification but the partition is the same
        for u in 0..8 {
            for v in 0..8 {
                assert_eq!(
                    initial[u] == initial[v],
                    c.labels[u] == c.labels[v]
                );
            }
        }
    }

    #[test]
    fn refinement_fixes_overload() {
        // Path of 6 nodes, block 0 holds 5 of them (overloaded for U=4).
        let mut b = GraphBuilder::new(6);
        for i in 1..6u32 {
            b.add_edge(i - 1, i, 1);
        }
        let g = b.build();
        let initial = vec![0, 0, 0, 0, 0, 1];
        let mut rng = Rng::new(2);
        let (c, _) = size_constrained_lpa(
            &g,
            4,
            &LpaConfig::refinement(10),
            Some(initial),
            None,
            &mut rng,
        );
        assert!(
            c.cluster_weights.iter().all(|&w| w <= 4),
            "{:?}",
            c.cluster_weights
        );
    }

    #[test]
    fn respect_partition_blocks_cross_moves() {
        let g = two_cliques();
        // Partition splits *within* each clique; clustering must respect it.
        let blocks = vec![0u32, 0, 1, 1, 0, 0, 1, 1];
        for seed in 0..6 {
            let mut rng = Rng::new(seed);
            let (c, _) = size_constrained_lpa(
                &g,
                8,
                &LpaConfig::clustering(10, NodeOrdering::Random),
                None,
                Some(&blocks),
                &mut rng,
            );
            for (u, v, _) in g.edges() {
                if blocks[u as usize] != blocks[v as usize] {
                    assert_ne!(
                        c.labels[u as usize], c.labels[v as usize],
                        "cluster crossed block boundary on edge ({u},{v})"
                    );
                }
            }
        }
    }

    #[test]
    fn isolated_nodes_stay_put() {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 1);
        let g = b.build();
        let mut rng = Rng::new(1);
        let (c, _) = size_constrained_lpa(&g, 4, &LpaConfig::default(), None, None, &mut rng);
        // isolated nodes keep their singleton clusters
        assert!(c.num_clusters >= 3);
    }

    #[test]
    fn converges_quickly_on_converged_input() {
        let g = two_cliques();
        let mut rng = Rng::new(9);
        let (c, _) = size_constrained_lpa(
            &g,
            4,
            &LpaConfig::clustering(10, NodeOrdering::Degree),
            None,
            None,
            &mut rng,
        );
        // Re-run from the converged labels: should stop after one round.
        let (_, rounds) = size_constrained_lpa(
            &g,
            4,
            &LpaConfig::clustering(10, NodeOrdering::Degree),
            Some(c.labels.clone()),
            None,
            &mut rng,
        );
        assert!(rounds <= 2, "rounds={rounds}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(42);
        let g = generators::barabasi_albert(500, 3, &mut rng);
        let run = |seed: u64| {
            let mut r = Rng::new(seed);
            size_constrained_lpa(
                &g,
                20,
                &LpaConfig::clustering(10, NodeOrdering::Degree),
                None,
                None,
                &mut r,
            )
            .0
            .labels
        };
        assert_eq!(run(1), run(1));
    }
}
