//! Clustering algorithms: the paper's size-constrained label propagation
//! (§3.1), ensemble overlay clustering (§4) and a shared-memory parallel
//! LPA (the paper's §6 future-work direction).

pub mod ensemble;
pub mod label_propagation;
pub mod parallel_lpa;

pub use ensemble::overlay_clustering;
pub use label_propagation::{
    size_constrained_lpa, Clustering, LpaConfig, LpaMode, NodeOrdering,
};
