//! Clustering algorithms: the paper's size-constrained label propagation
//! (§3.1), ensemble overlay clustering (§4), a shared-memory synchronous
//! parallel LPA (the paper's §6 future-work direction), the
//! coloring-based parallel *asynchronous* LPA of the companion work
//! (arXiv 1404.4797), and the semi-external streaming engine over
//! `graph::store` shards (arXiv 1404.4887; `external_lpa`).

pub mod async_lpa;
pub mod ensemble;
pub mod external_lpa;
pub mod label_propagation;
pub mod parallel_lpa;

pub use async_lpa::parallel_async_sclap;
pub use ensemble::overlay_clustering;
pub use external_lpa::{dense_from_labels, external_sclap};
pub use label_propagation::{
    size_constrained_lpa, size_constrained_lpa_ws, Clustering, LpaConfig, LpaMode,
    NodeOrdering,
};
