//! [`InMemoryStore`] — the [`GraphStore`] view of an existing CSR
//! [`Graph`]: shard views are zero-copy windows onto the graph's own
//! arrays, so `load` never copies, and any virtual shard count is free.
//!
//! Two uses: (1) the reference backend in the shard-invariance tests
//! (`ShardedStore` must be byte-identical to it), and (2) the "the
//! graph happens to fit, but the budgeted out-of-core algorithm was
//! requested" path of `partitioning::external::partition_store`.

use super::{shard_bounds, GraphStore, ShardCursor, ShardView};
use crate::graph::csr::{Graph, Weight};
use std::io;

/// Zero-copy [`GraphStore`] over a borrowed [`Graph`].
#[derive(Debug)]
pub struct InMemoryStore<'g> {
    graph: &'g Graph,
    bounds: Vec<usize>,
}

impl<'g> InMemoryStore<'g> {
    /// Single-shard view (the common case).
    pub fn new(graph: &'g Graph) -> Self {
        Self::with_shards(graph, 1)
    }

    /// View with `shards` contiguous virtual shards — free, since the
    /// views window one shared CSR; used to exercise shard-boundary
    /// handling without touching disk.
    pub fn with_shards(graph: &'g Graph, shards: usize) -> Self {
        InMemoryStore {
            graph,
            bounds: shard_bounds(graph.n(), shards),
        }
    }

    /// The underlying graph.
    pub fn graph(&self) -> &'g Graph {
        self.graph
    }
}

impl GraphStore for InMemoryStore<'_> {
    fn n(&self) -> usize {
        self.graph.n()
    }

    fn arc_count(&self) -> usize {
        self.graph.arc_count()
    }

    fn total_node_weight(&self) -> Weight {
        self.graph.total_node_weight()
    }

    fn max_node_weight(&self) -> Weight {
        self.graph.max_node_weight()
    }

    fn node_weights(&self) -> &[Weight] {
        self.graph.node_weights()
    }

    fn num_shards(&self) -> usize {
        self.bounds.len() - 1
    }

    fn shard_span(&self, shard: usize) -> (usize, usize) {
        (self.bounds[shard], self.bounds[shard + 1])
    }

    fn cursor(&self) -> Box<dyn ShardCursor + '_> {
        Box::new(InMemoryCursor {
            graph: self.graph,
            bounds: &self.bounds,
        })
    }

    fn memory_bytes(&self) -> u64 {
        self.graph.memory_bytes()
    }

    fn as_graph(&self) -> Option<&Graph> {
        Some(self.graph)
    }

    fn to_graph(&self) -> io::Result<Graph> {
        Ok(self.graph.clone())
    }
}

/// Cursor over an [`InMemoryStore`]: `load` slices the graph's CSR
/// arrays — no state, no copies, trivially allocation-free.
struct InMemoryCursor<'a> {
    graph: &'a Graph,
    bounds: &'a [usize],
}

impl ShardCursor for InMemoryCursor<'_> {
    fn load(&mut self, shard: usize) -> io::Result<ShardView<'_>> {
        let lo = self.bounds[shard];
        let hi = self.bounds[shard + 1];
        let (xadj, targets, weights) = self.graph.raw_csr();
        let a = xadj[lo];
        let b = xadj[hi];
        Ok(ShardView::new(
            lo,
            hi,
            &xadj[lo..=hi],
            &targets[a..b],
            &weights[a..b],
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::store::streaming_cut;

    fn sample() -> Graph {
        let mut b = GraphBuilder::new(6);
        b.add_edge(0, 1, 2);
        b.add_edge(1, 2, 1);
        b.add_edge(2, 3, 3);
        b.add_edge(3, 4, 1);
        b.add_edge(4, 5, 2);
        b.add_edge(0, 5, 1);
        b.set_node_weight(4, 7);
        b.build()
    }

    #[test]
    fn counts_mirror_the_graph() {
        let g = sample();
        let s = InMemoryStore::new(&g);
        assert_eq!(s.n(), g.n());
        assert_eq!(s.m(), g.m());
        assert_eq!(s.arc_count(), g.arc_count());
        assert_eq!(s.total_node_weight(), g.total_node_weight());
        assert_eq!(s.max_node_weight(), 7);
        assert_eq!(s.node_weights(), g.node_weights());
        assert_eq!(s.memory_bytes(), g.memory_bytes());
        assert_eq!(s.to_graph().unwrap(), g);
    }

    #[test]
    fn views_equal_graph_adjacency_for_any_shard_count() {
        let g = sample();
        for shards in [1usize, 2, 3, 4, 7] {
            let s = InMemoryStore::with_shards(&g, shards);
            assert_eq!(s.num_shards(), shards);
            let mut cursor = s.cursor();
            let mut seen = 0usize;
            for sh in 0..s.num_shards() {
                let view = cursor.load(sh).unwrap();
                let (lo, hi) = view.span();
                assert_eq!((lo, hi), s.shard_span(sh));
                for v in lo..hi {
                    let (adj, ws) = view.adjacent(v as u32);
                    assert_eq!(adj, g.adjacent(v as u32), "shards={shards} v={v}");
                    assert_eq!(ws, g.adjacent_weights(v as u32));
                    seen += 1;
                }
            }
            assert_eq!(seen, g.n());
        }
    }

    #[test]
    fn streaming_cut_matches_direct() {
        let g = sample();
        let labels = vec![0u32, 0, 1, 1, 2, 2];
        let direct = crate::partitioning::metrics::cut_value(&g, &labels);
        for shards in [1usize, 3, 6] {
            let s = InMemoryStore::with_shards(&g, shards);
            assert_eq!(streaming_cut(&s, &labels).unwrap(), direct);
        }
    }
}
