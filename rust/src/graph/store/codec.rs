//! `SCLAPS2` adjacency codec — canonical LEB128 varints, zigzag
//! signed mapping, and the per-node delta encoding of the compressed
//! shard format (byte layout in the `graph::store` module docs).
//!
//! # Encoding
//!
//! Arc lists arrive in the crate's canonical form (targets strictly
//! ascending, duplicates merged, weights in `1..=i64::MAX`), which the
//! codec exploits:
//!
//! - the first target is stored as `zigzag(t0 − v)` (neighbors cluster
//!   around the node id on locality-ordered graphs, so the magnitude is
//!   small either side of `v`);
//! - every later target as the gap `t[i] − t[i−1] − 1` (strict ascent
//!   makes the −1 free, so consecutive ids encode as 0);
//! - the first weight verbatim, later weights as zigzag deltas
//!   (unweighted graphs — all 1s — cost one byte for the first arc and
//!   one zero byte per arc after).
//!
//! # Canonical varints, hostile input
//!
//! [`read_varint`] accepts **only** the minimal LEB128 encoding (no
//! overlong forms, at most 10 bytes, final byte's payload within
//! `u64`). Every decoder entry point returns a structured
//! [`io::ErrorKind::InvalidData`]/[`io::ErrorKind::UnexpectedEof`]
//! error on malformed bytes — never a panic, and never an allocation
//! sized from untrusted input ([`decode_node`] bounds the claimed
//! degree by the caller's remaining arc budget before touching its
//! output buffers). One encoding per value also means re-encoding a
//! decode is byte-identical, which the round-trip property tests pin.

use crate::graph::csr::{NodeId, Weight};
use std::io;

/// Longest canonical LEB128 encoding of a `u64` (⌈64 / 7⌉ bytes).
pub const MAX_VARINT_BYTES: usize = 10;

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

fn truncated(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::UnexpectedEof, msg.to_string())
}

/// Map a signed value onto the unsigned varint domain so small
/// magnitudes of either sign stay small: 0, −1, 1, −2, … → 0, 1, 2, 3, …
#[inline]
pub fn zigzag_encode(x: i64) -> u64 {
    ((x << 1) ^ (x >> 63)) as u64
}

/// Inverse of [`zigzag_encode`].
#[inline]
pub fn zigzag_decode(x: u64) -> i64 {
    ((x >> 1) as i64) ^ -((x & 1) as i64)
}

/// Append the canonical (minimal) LEB128 encoding of `x`.
#[inline]
pub fn write_varint(out: &mut Vec<u8>, mut x: u64) {
    loop {
        let byte = (x & 0x7f) as u8;
        x >>= 7;
        if x == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Decode one canonical LEB128 varint from `buf` at `*pos`, advancing
/// `*pos` past it. Rejects truncation, encodings longer than
/// [`MAX_VARINT_BYTES`], a final byte overflowing `u64`, and overlong
/// (non-minimal) encodings such as `0x80 0x00`.
#[inline]
pub fn read_varint(buf: &[u8], pos: &mut usize) -> io::Result<u64> {
    let mut x: u64 = 0;
    let mut shift: u32 = 0;
    let mut i = *pos;
    loop {
        let Some(&b) = buf.get(i) else {
            return Err(truncated("varint truncated"));
        };
        i += 1;
        let payload = (b & 0x7f) as u64;
        if shift == 63 && payload > 1 {
            return Err(bad("varint overflows u64"));
        }
        x |= payload << shift;
        if b & 0x80 == 0 {
            if b == 0 && shift != 0 {
                return Err(bad("overlong varint encoding"));
            }
            *pos = i;
            return Ok(x);
        }
        shift += 7;
        if shift > 63 {
            return Err(bad("varint longer than 10 bytes"));
        }
    }
}

/// Append node `v`'s arc list (canonical form: targets strictly
/// ascending, weights positive) in the `SCLAPS2` per-node encoding:
/// degree, target deltas, then weight deltas.
pub fn encode_node(out: &mut Vec<u8>, v: NodeId, arcs: &[(NodeId, Weight)]) {
    debug_assert!(arcs.windows(2).all(|w| w[0].0 < w[1].0), "targets not strictly ascending");
    debug_assert!(arcs.iter().all(|&(_, w)| w >= 1), "non-positive edge weight");
    write_varint(out, arcs.len() as u64);
    if arcs.is_empty() {
        return;
    }
    write_varint(out, zigzag_encode(arcs[0].0 as i64 - v as i64));
    for w in arcs.windows(2) {
        write_varint(out, (w[1].0 - w[0].0 - 1) as u64);
    }
    write_varint(out, arcs[0].1 as u64);
    for w in arcs.windows(2) {
        write_varint(out, zigzag_encode(w[1].1 - w[0].1));
    }
}

/// Decode one node's arc list from `buf` at `*pos`, pushing targets and
/// weights onto the caller's (shared, pre-reserved) buffers. Returns
/// the decoded degree.
///
/// Validation (all structured errors, no panics):
/// - the claimed degree must not exceed `max_arcs` (the shard's
///   remaining arc budget — the unclamped-preallocation guard);
/// - every target must land in `0..n` and ascend strictly;
/// - every weight must stay in `1..=i64::MAX`;
/// - all arithmetic is checked (a hostile delta cannot wrap).
pub fn decode_node(
    buf: &[u8],
    pos: &mut usize,
    v: NodeId,
    n: usize,
    max_arcs: usize,
    targets: &mut Vec<NodeId>,
    weights: &mut Vec<Weight>,
) -> io::Result<usize> {
    let degree64 = read_varint(buf, pos)?;
    if degree64 > max_arcs as u64 {
        return Err(bad("node degree exceeds the shard's remaining arc budget"));
    }
    let degree = degree64 as usize;
    if degree == 0 {
        return Ok(0);
    }
    let first = zigzag_decode(read_varint(buf, pos)?);
    let t0 = (v as i64)
        .checked_add(first)
        .ok_or_else(|| bad("first target delta overflows"))?;
    if t0 < 0 || (t0 as u64) >= n as u64 {
        return Err(bad("shard arc target out of range"));
    }
    targets.push(t0 as NodeId);
    let mut prev = t0 as u64;
    for _ in 1..degree {
        let gap = read_varint(buf, pos)?;
        let t = prev
            .checked_add(gap)
            .and_then(|x| x.checked_add(1))
            .ok_or_else(|| bad("target delta overflows"))?;
        if t >= n as u64 {
            return Err(bad("shard arc target out of range"));
        }
        targets.push(t as NodeId);
        prev = t;
    }
    let w0 = read_varint(buf, pos)?;
    if w0 == 0 || w0 > i64::MAX as u64 {
        return Err(bad("shard edge weight out of range"));
    }
    weights.push(w0 as Weight);
    let mut prev_w = w0 as Weight;
    for _ in 1..degree {
        let delta = zigzag_decode(read_varint(buf, pos)?);
        let w = prev_w
            .checked_add(delta)
            .ok_or_else(|| bad("weight delta overflows"))?;
        if w <= 0 {
            return Err(bad("shard edge weight out of range"));
        }
        weights.push(w);
        prev_w = w;
    }
    Ok(degree)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{for_random_cases, PropConfig};

    #[test]
    fn zigzag_boundary_values() {
        for x in [0i64, 1, -1, 2, -2, 63, -64, i64::MAX, i64::MIN, i64::MIN + 1] {
            assert_eq!(zigzag_decode(zigzag_encode(x)), x, "{x}");
        }
        // Small magnitudes of either sign map to small codes.
        assert_eq!(zigzag_encode(0), 0);
        assert_eq!(zigzag_encode(-1), 1);
        assert_eq!(zigzag_encode(1), 2);
        assert_eq!(zigzag_encode(i64::MIN), u64::MAX);
    }

    #[test]
    fn varint_known_encodings() {
        let enc = |x: u64| {
            let mut b = Vec::new();
            write_varint(&mut b, x);
            b
        };
        assert_eq!(enc(0), vec![0x00]);
        assert_eq!(enc(1), vec![0x01]);
        assert_eq!(enc(127), vec![0x7f]);
        assert_eq!(enc(128), vec![0x80, 0x01]);
        assert_eq!(enc(300), vec![0xac, 0x02]);
        assert_eq!(enc(u64::MAX).len(), MAX_VARINT_BYTES);
    }

    /// Satellite: the codec round-trips arbitrary `(u64, i64)` sequences
    /// including boundary values, and re-encoding the parse is
    /// byte-identical (the `queue::spec` format→parse→format identity
    /// style, here format→parse→format on the byte stream).
    #[test]
    fn varint_zigzag_roundtrip_property() {
        let boundary_u = [0u64, 1, 2, 127, 128, 16_383, 16_384, u64::MAX - 1, u64::MAX];
        let boundary_i = [0i64, 1, -1, 63, -64, 64, i64::MAX, i64::MIN, i64::MIN + 1];
        for_random_cases(&PropConfig::default(), |rng, size| {
            let mut us: Vec<u64> = Vec::with_capacity(size);
            let mut is: Vec<i64> = Vec::with_capacity(size);
            for j in 0..size {
                if j % 3 == 0 {
                    // boundary values, including sign flips next to them
                    us.push(boundary_u[rng.below(boundary_u.len())]);
                    is.push(boundary_i[rng.below(boundary_i.len())]);
                } else {
                    // random magnitudes across the whole width spectrum
                    let shift = rng.below(64) as u32;
                    us.push(rng.next_u64() >> shift);
                    is.push((rng.next_u64() as i64) >> shift);
                }
            }
            let mut buf = Vec::new();
            for &u in &us {
                write_varint(&mut buf, u);
            }
            for &i in &is {
                write_varint(&mut buf, zigzag_encode(i));
            }
            let mut pos = 0usize;
            let mut reencoded = Vec::new();
            for &u in &us {
                let got = read_varint(&buf, &mut pos).expect("decode u64");
                assert_eq!(got, u);
                write_varint(&mut reencoded, got);
            }
            for &i in &is {
                let got = zigzag_decode(read_varint(&buf, &mut pos).expect("decode i64"));
                assert_eq!(got, i);
                write_varint(&mut reencoded, zigzag_encode(got));
            }
            assert_eq!(pos, buf.len(), "decoder must consume exactly the stream");
            assert_eq!(reencoded, buf, "canonical encoding must be unique");
        });
    }

    #[test]
    fn read_varint_rejects_hostile_bytes() {
        // Truncated: continuation bit set, no next byte.
        let mut pos = 0;
        let err = read_varint(&[0x80], &mut pos).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        // Empty input.
        let mut pos = 0;
        assert!(read_varint(&[], &mut pos).is_err());
        // Overlong: 0x80 0x00 encodes 0 in two bytes (minimal is 0x00).
        let mut pos = 0;
        let err = read_varint(&[0x80, 0x00], &mut pos).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // 11 continuation bytes: longer than any u64 encoding.
        let mut pos = 0;
        let long = [0xffu8; 11];
        assert!(read_varint(&long, &mut pos).is_err());
        // 10th byte carrying more than the top u64 bit: value overflow.
        let mut pos = 0;
        let mut overflow = [0xffu8; 10];
        overflow[9] = 0x02;
        assert!(read_varint(&overflow, &mut pos).is_err());
        // ...while the genuine u64::MAX encoding is accepted.
        let mut buf = Vec::new();
        write_varint(&mut buf, u64::MAX);
        let mut pos = 0;
        assert_eq!(read_varint(&buf, &mut pos).unwrap(), u64::MAX);
    }

    #[test]
    fn node_roundtrip_and_degree_budget() {
        let arcs: Vec<(NodeId, Weight)> = vec![(2, 5), (3, 1), (17, i64::MAX), (90, 7)];
        let mut buf = Vec::new();
        encode_node(&mut buf, 10, &arcs);
        let (mut targets, mut weights) = (Vec::new(), Vec::new());
        let mut pos = 0;
        let d = decode_node(&buf, &mut pos, 10, 100, arcs.len(), &mut targets, &mut weights)
            .unwrap();
        assert_eq!(d, arcs.len());
        assert_eq!(pos, buf.len());
        let decoded: Vec<(NodeId, Weight)> =
            targets.into_iter().zip(weights).collect();
        assert_eq!(decoded, arcs);
        // The same bytes with a tighter arc budget: structured error,
        // nothing pushed beyond the check.
        let (mut t2, mut w2) = (Vec::new(), Vec::new());
        let mut pos = 0;
        let err = decode_node(&buf, &mut pos, 10, 100, 3, &mut t2, &mut w2).unwrap_err();
        assert!(err.to_string().contains("budget"), "{err}");
        assert!(t2.is_empty() && w2.is_empty());
    }

    #[test]
    fn empty_adjacency_is_one_byte() {
        let mut buf = Vec::new();
        encode_node(&mut buf, 4, &[]);
        assert_eq!(buf, vec![0x00]);
        let (mut t, mut w) = (Vec::new(), Vec::new());
        let mut pos = 0;
        assert_eq!(decode_node(&buf, &mut pos, 4, 8, 0, &mut t, &mut w).unwrap(), 0);
        assert!(t.is_empty() && w.is_empty());
    }

    #[test]
    fn node_property_roundtrip() {
        // Random canonical arc lists (sorted unique targets, positive
        // weights) round-trip exactly for random node ids.
        for_random_cases(&PropConfig::default(), |rng, size| {
            let n = 2 * size + 8;
            let v = rng.below(n) as NodeId;
            let mut targets: Vec<NodeId> =
                (0..size).map(|_| rng.below(n) as NodeId).collect();
            targets.sort_unstable();
            targets.dedup();
            let arcs: Vec<(NodeId, Weight)> = targets
                .into_iter()
                .map(|t| (t, 1 + rng.below(1 << 30) as Weight))
                .collect();
            let mut buf = Vec::new();
            encode_node(&mut buf, v, &arcs);
            let (mut t, mut w) = (Vec::new(), Vec::new());
            let mut pos = 0;
            let d = decode_node(&buf, &mut pos, v, n, arcs.len(), &mut t, &mut w).unwrap();
            assert_eq!(d, arcs.len());
            assert_eq!(pos, buf.len());
            assert_eq!(t, arcs.iter().map(|&(t, _)| t).collect::<Vec<_>>());
            assert_eq!(w, arcs.iter().map(|&(_, w)| w).collect::<Vec<_>>());
        });
    }

    #[test]
    fn decode_node_rejects_corrupt_streams() {
        let n = 100usize;
        let check_err = |bytes: &[u8], max_arcs: usize| {
            let (mut t, mut w) = (Vec::new(), Vec::new());
            let mut pos = 0;
            decode_node(bytes, &mut pos, 50, n, max_arcs, &mut t, &mut w)
                .expect_err("hostile bytes must error")
        };
        // Degree claims more than the budget (huge claimed length).
        let mut huge = Vec::new();
        write_varint(&mut huge, u64::MAX);
        assert!(check_err(&huge, 10).to_string().contains("budget"));
        // Target out of range: first target beyond n.
        let mut far = Vec::new();
        write_varint(&mut far, 1);
        write_varint(&mut far, zigzag_encode(n as i64)); // 50 + 100 >= n
        check_err(&far, 10);
        // Gap pushing a later target past n.
        let mut gap = Vec::new();
        write_varint(&mut gap, 2);
        write_varint(&mut gap, zigzag_encode(0)); // t0 = 50
        write_varint(&mut gap, n as u64); // t1 = 50 + n + 1
        check_err(&gap, 10);
        // Zero weight.
        let mut zero_w = Vec::new();
        write_varint(&mut zero_w, 1);
        write_varint(&mut zero_w, zigzag_encode(1));
        write_varint(&mut zero_w, 0);
        assert!(check_err(&zero_w, 10).to_string().contains("weight"));
        // Weight delta driving the running weight non-positive.
        let mut neg = Vec::new();
        write_varint(&mut neg, 2);
        write_varint(&mut neg, zigzag_encode(1));
        write_varint(&mut neg, 0);
        write_varint(&mut neg, 3); // w0 = 3
        write_varint(&mut neg, zigzag_encode(-3)); // w1 = 0
        check_err(&neg, 10);
        // Truncated mid-list.
        let mut trunc = Vec::new();
        write_varint(&mut trunc, 3);
        write_varint(&mut trunc, zigzag_encode(1));
        check_err(&trunc, 10);
    }
}
