//! [`ShardedStore`] — the on-disk [`GraphStore`]: a directory of
//! independent CSR shard segments plus a meta file holding the resident
//! node state (format documented in the `graph::store` module docs).
//!
//! Peak-memory discipline:
//! - [`convert_metis_to_shards`] streams the METIS file row by row and
//!   buffers **one shard's** degrees/arcs before flushing it to disk —
//!   the full graph is never materialized (node weights, O(n), are the
//!   only whole-graph state, as the semi-external model allows);
//! - [`ShardFileCursor`] owns three grow-only buffers (`xadj`,
//!   `targets`, `weights`) that are cleared and refilled on every
//!   `load` — at most one shard resident, allocation-free once the
//!   buffers have grown to the largest shard.

use super::{codec, fnv1a_bytes, shard_bounds, GraphStore, ShardCursor, ShardFormat, ShardView};
use crate::graph::csr::{csr_footprint_bytes, EdgeId, Graph, NodeId, Weight};
use crate::graph::io::{read_bytes_capped, read_u64, MetisReader, MetisRow};
use crate::util::rng::splitmix64;
use std::fs::File;
use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::time::SystemTime;

const META_MAGIC: &[u8; 8] = b"SCLAPM1\0";
const SHARD_MAGIC: &[u8; 8] = b"SCLAPS1\0";
const SHARD_MAGIC_V2: &[u8; 8] = b"SCLAPS2\0";

/// Nodes per `SCLAPS2` block-index entry. 1024 nodes keeps the index
/// tiny (16 bytes per KiNode) while bounding how far a random-access
/// reader would ever have to decode past an index point.
pub const BLOCK_NODES: usize = 1024;

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

fn write_u64<W: Write>(out: &mut W, x: u64) -> io::Result<()> {
    out.write_all(&x.to_le_bytes())
}

/// On-disk sharded CSR store. Opening reads only `meta.bin` (node
/// weights + shard table); adjacency stays on disk until a cursor
/// streams it.
#[derive(Debug)]
pub struct ShardedStore {
    dir: PathBuf,
    arcs: usize,
    bounds: Vec<usize>,
    node_weights: Vec<Weight>,
    total_node_weight: Weight,
    max_node_weight: Weight,
    format: ShardFormat,
}

impl ShardedStore {
    /// Open a shard directory written by [`write_sharded`] /
    /// [`convert_metis_to_shards`].
    pub fn open(dir: &Path) -> io::Result<ShardedStore> {
        let file = File::open(dir.join("meta.bin"))?;
        let mut r = BufReader::new(file);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != META_MAGIC {
            return Err(bad("bad shard-store meta magic"));
        }
        let version = read_u64(&mut r)?;
        let Some(format) = ShardFormat::from_version(version) else {
            return Err(bad(&format!("unsupported shard format version {version}")));
        };
        let n_raw = read_u64(&mut r)?;
        if n_raw > u32::MAX as u64 {
            return Err(bad("node count out of range"));
        }
        let n = n_raw as usize;
        let arcs = read_u64(&mut r)? as usize;
        let shards = read_u64(&mut r)? as usize;
        if shards == 0 || shards > n.max(1) * 2 + 64 {
            return Err(bad("implausible shard count"));
        }
        let mut bounds = Vec::with_capacity(shards + 1);
        for _ in 0..=shards {
            bounds.push(read_u64(&mut r)? as usize);
        }
        if bounds[0] != 0 || bounds[shards] != n || bounds.windows(2).any(|w| w[0] > w[1]) {
            return Err(bad("shard bounds not a monotone cover of 0..n"));
        }
        let mut node_weights = Vec::with_capacity(n.min(1 << 24));
        for _ in 0..n {
            let w = read_u64(&mut r)?;
            if w > i64::MAX as u64 {
                return Err(bad("node weight out of range"));
            }
            node_weights.push(w as Weight);
        }
        let total_node_weight = node_weights.iter().sum();
        let max_node_weight = node_weights.iter().copied().max().unwrap_or(0);
        Ok(ShardedStore {
            dir: dir.to_path_buf(),
            arcs,
            bounds,
            node_weights,
            total_node_weight,
            max_node_weight,
            format,
        })
    }

    /// Directory this store lives in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The format declared by `meta.bin`. Individual shard files are
    /// still auto-detected per magic on load (a partially-recompressed
    /// directory with mixed shard versions reads fine), so this is the
    /// *advertised* format, used for reporting and as the recompress
    /// default.
    pub fn format(&self) -> ShardFormat {
        self.format
    }

    fn shard_path(&self, shard: usize) -> PathBuf {
        self.dir.join(format!("shard_{shard}.bin"))
    }

    /// Total on-disk bytes of meta + shard files (for IO-throughput
    /// reporting; distinct from [`GraphStore::memory_bytes`], which is
    /// the *in-RAM* CSR footprint).
    pub fn disk_bytes(&self) -> io::Result<u64> {
        let mut total = std::fs::metadata(self.dir.join("meta.bin"))?.len();
        for s in 0..self.num_shards() {
            total += std::fs::metadata(self.shard_path(s))?.len();
        }
        Ok(total)
    }
}

impl GraphStore for ShardedStore {
    fn n(&self) -> usize {
        self.node_weights.len()
    }

    fn arc_count(&self) -> usize {
        self.arcs
    }

    fn total_node_weight(&self) -> Weight {
        self.total_node_weight
    }

    fn max_node_weight(&self) -> Weight {
        self.max_node_weight
    }

    fn node_weights(&self) -> &[Weight] {
        &self.node_weights
    }

    fn num_shards(&self) -> usize {
        self.bounds.len() - 1
    }

    fn shard_span(&self, shard: usize) -> (usize, usize) {
        (self.bounds[shard], self.bounds[shard + 1])
    }

    fn cursor(&self) -> Box<dyn ShardCursor + '_> {
        Box::new(ShardFileCursor::new(self))
    }

    fn memory_bytes(&self) -> u64 {
        csr_footprint_bytes(self.n(), self.arcs)
    }

    fn to_graph(&self) -> io::Result<Graph> {
        let n = self.n();
        let mut xadj: Vec<EdgeId> = Vec::with_capacity(n + 1);
        xadj.push(0);
        let mut targets: Vec<NodeId> = Vec::with_capacity(self.arcs.min(1 << 26));
        let mut weights: Vec<Weight> = Vec::with_capacity(self.arcs.min(1 << 26));
        let mut cursor = self.cursor();
        for s in 0..self.num_shards() {
            let view = cursor.load(s)?;
            let (lo, hi) = view.span();
            for v in lo..hi {
                let (adj, ws) = view.adjacent(v as NodeId);
                targets.extend_from_slice(adj);
                weights.extend_from_slice(ws);
                xadj.push(targets.len());
            }
        }
        if xadj.len() != n + 1 || targets.len() != self.arcs {
            return Err(bad("shard files inconsistent with meta"));
        }
        Ok(Graph::from_csr(xadj, targets, weights, self.node_weights.clone()))
    }
}

/// Streaming cursor over a [`ShardedStore`]: one shard resident,
/// reusable grow-only buffers, no allocation after warm-up (see module
/// docs). The on-disk format is detected per shard file from its magic
/// (`SCLAPS1` raw / `SCLAPS2` compressed), so one cursor reads either —
/// or a mixed directory.
pub struct ShardFileCursor<'a> {
    store: &'a ShardedStore,
    xadj: Vec<EdgeId>,
    targets: Vec<NodeId>,
    weights: Vec<Weight>,
    /// v2 only: raw compressed payload of the resident shard.
    payload: Vec<u8>,
    /// v2 only: decoded block index of the resident shard.
    index: Vec<(u64, u64)>,
    loaded: Option<usize>,
    loads: usize,
}

impl<'a> ShardFileCursor<'a> {
    /// Fresh cursor with empty (grow-only) buffers.
    pub fn new(store: &'a ShardedStore) -> ShardFileCursor<'a> {
        ShardFileCursor {
            store,
            xadj: Vec::new(),
            targets: Vec::new(),
            weights: Vec::new(),
            payload: Vec::new(),
            index: Vec::new(),
            loaded: None,
            loads: 0,
        }
    }

    /// Number of shard files read from disk so far (re-loading the
    /// resident shard is free and not counted) — the observable for
    /// "each pass touches each shard once".
    pub fn disk_loads(&self) -> usize {
        self.loads
    }

    fn read_shard(&mut self, shard: usize) -> io::Result<()> {
        let (lo, hi) = self.store.shard_span(shard);
        let file = File::open(self.store.shard_path(shard))?;
        let mut r = BufReader::new(file);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic == SHARD_MAGIC {
            self.read_shard_v1(&mut r, lo, hi)
        } else if &magic == SHARD_MAGIC_V2 {
            self.read_shard_v2(&mut r, lo, hi)
        } else {
            Err(bad("bad shard magic"))
        }
    }

    fn read_shard_v1<R: Read>(&mut self, r: &mut R, lo: usize, hi: usize) -> io::Result<()> {
        if read_u64(r)? != ShardFormat::V1.version() {
            return Err(bad("unsupported shard format version"));
        }
        let (flo, fhi) = (read_u64(r)? as usize, read_u64(r)? as usize);
        if (flo, fhi) != (lo, hi) {
            return Err(bad("shard span disagrees with meta"));
        }
        let arcs = read_u64(r)? as usize;
        if arcs > self.store.arcs {
            return Err(bad("shard arc count exceeds store total"));
        }
        let n = self.store.n();
        self.xadj.clear();
        self.xadj.reserve(hi - lo + 1);
        self.xadj.push(0);
        for _ in lo..hi {
            let d = read_u64(r)? as usize;
            let next = self
                .xadj
                .last()
                .unwrap()
                .checked_add(d)
                .ok_or_else(|| bad("degree sum overflows"))?;
            self.xadj.push(next);
        }
        if *self.xadj.last().unwrap() != arcs {
            return Err(bad("shard degree sum != arc count"));
        }
        // Clamp pre-reservation (the `read_binary` convention): a
        // corrupt header must surface as an `InvalidData`/EOF error,
        // never as an allocation abort.
        self.targets.clear();
        self.targets.reserve(arcs.min(1 << 26));
        self.weights.clear();
        self.weights.reserve(arcs.min(1 << 26));
        for _ in 0..arcs {
            let t = read_u64(r)?;
            if t >= n as u64 {
                return Err(bad("shard arc target out of range"));
            }
            self.targets.push(t as NodeId);
            let w = read_u64(r)?;
            if w == 0 || w > i64::MAX as u64 {
                return Err(bad("shard edge weight out of range"));
            }
            self.weights.push(w as Weight);
        }
        Ok(())
    }

    /// `SCLAPS2` body: header + block index + compressed payload
    /// (layout in the module docs). Every header quantity is bounded
    /// against meta-validated state before any allocation, and the
    /// block index is cross-checked against the running decode position
    /// at every block boundary, so a lying index or a corrupt payload
    /// is always a structured error.
    fn read_shard_v2<R: Read>(&mut self, r: &mut R, lo: usize, hi: usize) -> io::Result<()> {
        if read_u64(r)? != ShardFormat::V2.version() {
            return Err(bad("unsupported shard format version"));
        }
        let (flo, fhi) = (read_u64(r)? as usize, read_u64(r)? as usize);
        if (flo, fhi) != (lo, hi) {
            return Err(bad("shard span disagrees with meta"));
        }
        let arcs = read_u64(r)? as usize;
        if arcs > self.store.arcs {
            return Err(bad("shard arc count exceeds store total"));
        }
        let block_nodes = read_u64(r)? as usize;
        if block_nodes == 0 {
            return Err(bad("shard block size must be positive"));
        }
        let nblocks = read_u64(r)? as usize;
        // The span is meta-validated, so this also bounds nblocks.
        if nblocks != (hi - lo).div_ceil(block_nodes) {
            return Err(bad("shard block count disagrees with span"));
        }
        let payload_len = read_u64(r)?;
        self.index.clear();
        self.index.reserve(nblocks);
        for b in 0..nblocks {
            let off = read_u64(r)?;
            let arc_start = read_u64(r)?;
            if off > payload_len || arc_start > arcs as u64 {
                return Err(bad("shard block index entry out of range"));
            }
            if b == 0 && (off, arc_start) != (0, 0) {
                return Err(bad("shard block index must start at (0, 0)"));
            }
            if let Some(&(prev_off, prev_arc)) = self.index.last() {
                if off < prev_off || arc_start < prev_arc {
                    return Err(bad("shard block index not monotone"));
                }
            }
            self.index.push((off, arc_start));
        }
        read_bytes_capped(r, payload_len, 1 << 26, &mut self.payload)?;
        let n = self.store.n();
        self.xadj.clear();
        self.xadj.reserve(hi - lo + 1);
        self.xadj.push(0);
        self.targets.clear();
        self.targets.reserve(arcs.min(1 << 26));
        self.weights.clear();
        self.weights.reserve(arcs.min(1 << 26));
        let mut pos = 0usize;
        for (i, v) in (lo..hi).enumerate() {
            if i % block_nodes == 0 {
                let (off, arc_start) = self.index[i / block_nodes];
                if pos as u64 != off || self.targets.len() as u64 != arc_start {
                    return Err(bad("shard block index disagrees with payload"));
                }
            }
            let remaining = arcs - self.targets.len();
            codec::decode_node(
                &self.payload,
                &mut pos,
                v as NodeId,
                n,
                remaining,
                &mut self.targets,
                &mut self.weights,
            )?;
            self.xadj.push(self.targets.len());
        }
        if pos != self.payload.len() {
            return Err(bad("trailing bytes after shard payload"));
        }
        if self.targets.len() != arcs {
            return Err(bad("shard degree sum != arc count"));
        }
        Ok(())
    }
}

impl ShardCursor for ShardFileCursor<'_> {
    fn load(&mut self, shard: usize) -> io::Result<ShardView<'_>> {
        if self.loaded != Some(shard) {
            // Invalidate BEFORE reading: a failed read_shard leaves the
            // buffers partially clobbered, and `loaded` must not keep
            // naming the previous shard (a later re-load of it would
            // short-circuit onto garbage).
            self.loaded = None;
            self.read_shard(shard)?;
            self.loaded = Some(shard);
            self.loads += 1;
        }
        let (lo, hi) = self.store.shard_span(shard);
        Ok(ShardView::new(lo, hi, &self.xadj, &self.targets, &self.weights))
    }
}

fn write_shard_file(
    dir: &Path,
    shard: usize,
    lo: usize,
    hi: usize,
    degrees: &[u64],
    arcs: &[(NodeId, Weight)],
    format: ShardFormat,
) -> io::Result<()> {
    debug_assert_eq!(degrees.len(), hi - lo);
    debug_assert_eq!(degrees.iter().sum::<u64>() as usize, arcs.len());
    match format {
        ShardFormat::V1 => write_shard_file_v1(dir, shard, lo, hi, degrees, arcs),
        ShardFormat::V2 => write_shard_file_v2(dir, shard, lo, hi, degrees, arcs),
    }
}

fn write_shard_file_v1(
    dir: &Path,
    shard: usize,
    lo: usize,
    hi: usize,
    degrees: &[u64],
    arcs: &[(NodeId, Weight)],
) -> io::Result<()> {
    let file = File::create(dir.join(format!("shard_{shard}.bin")))?;
    let mut out = BufWriter::new(file);
    out.write_all(SHARD_MAGIC)?;
    write_u64(&mut out, ShardFormat::V1.version())?;
    write_u64(&mut out, lo as u64)?;
    write_u64(&mut out, hi as u64)?;
    write_u64(&mut out, arcs.len() as u64)?;
    for &d in degrees {
        write_u64(&mut out, d)?;
    }
    for &(t, w) in arcs {
        write_u64(&mut out, t as u64)?;
        write_u64(&mut out, w as u64)?;
    }
    out.flush()
}

fn write_shard_file_v2(
    dir: &Path,
    shard: usize,
    lo: usize,
    hi: usize,
    degrees: &[u64],
    arcs: &[(NodeId, Weight)],
) -> io::Result<()> {
    let nblocks = (hi - lo).div_ceil(BLOCK_NODES);
    let mut payload: Vec<u8> = Vec::new();
    let mut index: Vec<(u64, u64)> = Vec::with_capacity(nblocks);
    let mut arc_pos = 0usize;
    for (i, &d) in degrees.iter().enumerate() {
        if i % BLOCK_NODES == 0 {
            index.push((payload.len() as u64, arc_pos as u64));
        }
        let d = d as usize;
        codec::encode_node(&mut payload, (lo + i) as NodeId, &arcs[arc_pos..arc_pos + d]);
        arc_pos += d;
    }
    debug_assert_eq!(arc_pos, arcs.len());
    debug_assert_eq!(index.len(), nblocks);
    let file = File::create(dir.join(format!("shard_{shard}.bin")))?;
    let mut out = BufWriter::new(file);
    out.write_all(SHARD_MAGIC_V2)?;
    write_u64(&mut out, ShardFormat::V2.version())?;
    write_u64(&mut out, lo as u64)?;
    write_u64(&mut out, hi as u64)?;
    write_u64(&mut out, arcs.len() as u64)?;
    write_u64(&mut out, BLOCK_NODES as u64)?;
    write_u64(&mut out, nblocks as u64)?;
    write_u64(&mut out, payload.len() as u64)?;
    for &(off, arc_start) in &index {
        write_u64(&mut out, off)?;
        write_u64(&mut out, arc_start)?;
    }
    out.write_all(&payload)?;
    out.flush()
}

fn write_meta(
    dir: &Path,
    n: usize,
    arcs: u64,
    bounds: &[usize],
    node_weights: &[Weight],
    format: ShardFormat,
) -> io::Result<()> {
    let file = File::create(dir.join("meta.bin"))?;
    let mut out = BufWriter::new(file);
    out.write_all(META_MAGIC)?;
    write_u64(&mut out, format.version())?;
    write_u64(&mut out, n as u64)?;
    write_u64(&mut out, arcs)?;
    write_u64(&mut out, (bounds.len() - 1) as u64)?;
    for &b in bounds {
        write_u64(&mut out, b as u64)?;
    }
    for &w in node_weights {
        write_u64(&mut out, w as u64)?;
    }
    out.flush()
}

/// Validation stamp of a shard directory's `meta.bin`, used by
/// `coordinator::net::cache` to decide whether a memoized fingerprint
/// is still current. Beyond `(length, mtime)` it folds in the declared
/// format version and an FNV-1a hash of the file's full content, so a
/// rewrite that lands within mtime granularity at equal length (e.g. a
/// recompress, or same-n regeneration with different node weights) can
/// never validate a stale entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetaStamp {
    len: u64,
    mtime: Option<SystemTime>,
    format_version: u64,
    content_fnv: u64,
}

impl MetaStamp {
    /// Format version declared by the stamped `meta.bin` (0 when the
    /// file is not a shard meta at all).
    pub fn format_version(&self) -> u64 {
        self.format_version
    }

    /// FNV-1a 64 over the full `meta.bin` bytes.
    pub fn content_fnv(&self) -> u64 {
        self.content_fnv
    }
}

/// Compute the [`MetaStamp`] of `dir`'s `meta.bin`. Reads the whole
/// file — O(n) bytes, cheap next to re-streaming every shard, which is
/// exactly what a valid stamp lets the fingerprint memo skip.
pub fn meta_stamp(dir: &Path) -> io::Result<MetaStamp> {
    let path = dir.join("meta.bin");
    let meta = std::fs::metadata(&path)?;
    let bytes = std::fs::read(&path)?;
    let format_version = if bytes.len() >= 16 && bytes[0..8] == META_MAGIC[..] {
        u64::from_le_bytes(bytes[8..16].try_into().unwrap())
    } else {
        0
    };
    Ok(MetaStamp {
        len: meta.len(),
        mtime: meta.modified().ok(),
        format_version,
        content_fnv: fnv1a_bytes(&bytes),
    })
}

/// [`write_sharded_as`] in the v1 format (the library default — keeps
/// existing callers and their on-disk expectations unchanged; the CLI
/// defaults to v2).
pub fn write_sharded(graph: &Graph, dir: &Path, shards: usize) -> io::Result<ShardedStore> {
    write_sharded_as(graph, dir, shards, ShardFormat::V1)
}

/// Write `graph` as a shard directory with `shards` contiguous shards
/// in the requested format (for `.bin`/edge-list inputs and benches;
/// METIS files should go through the streaming
/// [`convert_metis_to_shards_as`] instead).
pub fn write_sharded_as(
    graph: &Graph,
    dir: &Path,
    shards: usize,
    format: ShardFormat,
) -> io::Result<ShardedStore> {
    if graph.n() > u32::MAX as usize {
        return Err(bad("node count out of range"));
    }
    std::fs::create_dir_all(dir)?;
    let bounds = shard_bounds(graph.n(), shards);
    let mut degrees: Vec<u64> = Vec::new();
    let mut arcs: Vec<(NodeId, Weight)> = Vec::new();
    for s in 0..bounds.len() - 1 {
        let (lo, hi) = (bounds[s], bounds[s + 1]);
        degrees.clear();
        arcs.clear();
        for v in lo..hi {
            degrees.push(graph.degree(v as NodeId) as u64);
            for (u, w) in graph.neighbors(v as NodeId) {
                arcs.push((u, w));
            }
        }
        write_shard_file(dir, s, lo, hi, &degrees, &arcs, format)?;
    }
    write_meta(
        dir,
        graph.n(),
        graph.arc_count() as u64,
        &bounds,
        graph.node_weights(),
        format,
    )?;
    ShardedStore::open(dir)
}

/// Rewrite the shard directory at `src` into `dst`, optionally
/// re-sharding, in the requested format — the `shard recompress` CLI
/// verb. Streams `src` one shard at a time (peak memory: one input
/// shard + one output shard), so recompressing a store that never fit
/// in RAM stays out-of-core. The logical CSR stream is preserved
/// exactly, so the result has identical [`store_fingerprints`] and
/// yields byte-identical partitions.
pub fn recompress_store(
    src: &Path,
    dst: &Path,
    shards: Option<usize>,
    format: ShardFormat,
) -> io::Result<ShardedStore> {
    let store = ShardedStore::open(src)?;
    std::fs::create_dir_all(dst)?;
    if let (Ok(a), Ok(b)) = (std::fs::canonicalize(src), std::fs::canonicalize(dst)) {
        if a == b {
            return Err(bad("recompress target must differ from the source directory"));
        }
    }
    let out_shards = shards.unwrap_or_else(|| store.num_shards());
    let bounds = shard_bounds(store.n(), out_shards);
    let num_shards = bounds.len() - 1;
    let mut degrees: Vec<u64> = Vec::new();
    let mut arcs: Vec<(NodeId, Weight)> = Vec::new();
    let mut shard = 0usize;
    let mut total_arcs: u64 = 0;
    let mut cursor = store.cursor();
    for s in 0..store.num_shards() {
        let view = cursor.load(s)?;
        let (lo, hi) = view.span();
        for v in lo..hi {
            while v >= bounds[shard + 1] {
                write_shard_file(dst, shard, bounds[shard], bounds[shard + 1], &degrees, &arcs, format)?;
                degrees.clear();
                arcs.clear();
                shard += 1;
            }
            let (adj, ws) = view.adjacent(v as NodeId);
            degrees.push(adj.len() as u64);
            for (&t, &w) in adj.iter().zip(ws) {
                arcs.push((t, w));
            }
            total_arcs += adj.len() as u64;
        }
    }
    while shard < num_shards {
        write_shard_file(dst, shard, bounds[shard], bounds[shard + 1], &degrees, &arcs, format)?;
        degrees.clear();
        arcs.clear();
        shard += 1;
    }
    drop(cursor);
    debug_assert_eq!(total_arcs as usize, store.arc_count());
    write_meta(dst, store.n(), total_arcs, &bounds, store.node_weights(), format)?;
    ShardedStore::open(dst)
}

/// Streaming METIS → shard-directory converter. Reads the file once,
/// row by row ([`MetisReader`]), holding only the *current* shard's
/// degrees and arcs plus the O(n) node-weight array — the full
/// adjacency is never materialized, so graphs far beyond RAM convert
/// in bounded memory. The rows are written in the canonical
/// sorted/deduped form, making the resulting store arc-for-arc
/// identical to `read_metis` + [`write_sharded`].
///
/// Symmetry guard: `read_metis` *symmetrizes* (it keeps the low-
/// endpoint copy of each edge), while this converter writes rows
/// verbatim — an asymmetric file would make the two backends diverge
/// silently. A streaming O(1)-state check (a direction-signed
/// commutative hash over `(min, max, ω)` per arc, which must cancel to
/// zero on a symmetric file) rejects such inputs; collisions are
/// astronomically unlikely, never false positives.
pub fn convert_metis_to_shards<R: BufRead>(
    reader: R,
    dir: &Path,
    shards: usize,
) -> io::Result<ShardedStore> {
    convert_metis_to_shards_as(reader, dir, shards, ShardFormat::V1)
}

/// [`convert_metis_to_shards`] with an explicit output format.
pub fn convert_metis_to_shards_as<R: BufRead>(
    reader: R,
    dir: &Path,
    shards: usize,
    format: ShardFormat,
) -> io::Result<ShardedStore> {
    let mut metis = MetisReader::new(reader)?;
    let n = metis.n;
    if n > u32::MAX as usize {
        return Err(bad("node count out of range"));
    }
    std::fs::create_dir_all(dir)?;
    let bounds = shard_bounds(n, shards);
    let num_shards = bounds.len() - 1;
    let mut node_weights: Vec<Weight> = Vec::with_capacity(n);
    let mut degrees: Vec<u64> = Vec::new();
    let mut arcs: Vec<(NodeId, Weight)> = Vec::new();
    let mut shard = 0usize;
    let mut total_arcs: u64 = 0;
    let mut sym_hash: u64 = 0;
    let mut row = MetisRow::default();
    let mut v = 0usize;
    while metis.next_row(&mut row)? {
        while v >= bounds[shard + 1] {
            write_shard_file(dir, shard, bounds[shard], bounds[shard + 1], &degrees, &arcs, format)?;
            degrees.clear();
            arcs.clear();
            shard += 1;
        }
        for &(u, w) in &row.neighbors {
            let (a, b) = ((v as u64).min(u as u64), (v as u64).max(u as u64));
            let h = splitmix64(a ^ splitmix64(b ^ splitmix64(w as u64)));
            if (u as usize) > v {
                sym_hash = sym_hash.wrapping_add(h);
            } else {
                sym_hash = sym_hash.wrapping_sub(h);
            }
        }
        node_weights.push(row.node_weight);
        degrees.push(row.neighbors.len() as u64);
        arcs.extend_from_slice(&row.neighbors);
        total_arcs += row.neighbors.len() as u64;
        v += 1;
    }
    while shard < num_shards {
        write_shard_file(dir, shard, bounds[shard], bounds[shard + 1], &degrees, &arcs, format)?;
        degrees.clear();
        arcs.clear();
        shard += 1;
    }
    if sym_hash != 0 {
        return Err(bad(
            "asymmetric METIS adjacency: some edge is listed only once or with \
             direction-dependent weight (in-memory parsing would symmetrize and diverge)",
        ));
    }
    metis.check_edge_count((total_arcs / 2) as usize)?;
    write_meta(dir, n, total_arcs, &bounds, &node_weights, format)?;
    ShardedStore::open(dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::io::{read_metis, write_metis};
    use crate::graph::store::streaming_cut;
    use crate::util::rng::Rng;
    use std::io::Cursor;

    fn temp_dir(label: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "sclap-store-{}-{label}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample() -> Graph {
        let mut rng = Rng::new(3);
        crate::generators::barabasi_albert(300, 3, &mut rng)
    }

    #[test]
    fn write_open_roundtrip_any_shard_count() {
        let g = sample();
        for shards in [1usize, 2, 5, 7] {
            let dir = temp_dir(&format!("rt{shards}"));
            let store = write_sharded(&g, &dir, shards).unwrap();
            assert_eq!(store.n(), g.n());
            assert_eq!(store.m(), g.m());
            assert_eq!(store.num_shards(), shards);
            assert_eq!(store.node_weights(), g.node_weights());
            assert_eq!(store.memory_bytes(), g.memory_bytes());
            assert_eq!(store.to_graph().unwrap(), g);
            // reopen from disk
            let reopened = ShardedStore::open(&dir).unwrap();
            assert_eq!(reopened.to_graph().unwrap(), g);
            assert!(reopened.disk_bytes().unwrap() > 0);
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn metis_conversion_matches_in_memory_parse() {
        let g = sample();
        let mut buf = Vec::new();
        write_metis(&g, &mut buf).unwrap();
        let reference = read_metis(Cursor::new(&buf)).unwrap();
        for shards in [1usize, 2, 7] {
            let dir = temp_dir(&format!("conv{shards}"));
            let store =
                convert_metis_to_shards(Cursor::new(&buf), &dir, shards).unwrap();
            assert_eq!(store.to_graph().unwrap(), reference, "shards={shards}");
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn cursor_streams_each_shard_once_per_pass() {
        let g = sample();
        let dir = temp_dir("passes");
        let store = write_sharded(&g, &dir, 4).unwrap();
        let mut cursor = ShardFileCursor::new(&store);
        for s in 0..4 {
            // repeated loads of the resident shard hit the buffer
            let a = cursor.load(s).unwrap().arc_count();
            let b = cursor.load(s).unwrap().arc_count();
            assert_eq!(a, b);
        }
        assert_eq!(cursor.disk_loads(), 4);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn v2_roundtrip_and_size() {
        let g = sample();
        for shards in [1usize, 2, 5] {
            let dir1 = temp_dir(&format!("v2a{shards}"));
            let dir2 = temp_dir(&format!("v2b{shards}"));
            let v1 = write_sharded_as(&g, &dir1, shards, ShardFormat::V1).unwrap();
            let v2 = write_sharded_as(&g, &dir2, shards, ShardFormat::V2).unwrap();
            assert_eq!(v1.format(), ShardFormat::V1);
            assert_eq!(v2.format(), ShardFormat::V2);
            assert_eq!(v2.to_graph().unwrap(), g, "shards={shards}");
            assert_eq!(ShardedStore::open(&dir2).unwrap().to_graph().unwrap(), g);
            assert!(
                v2.disk_bytes().unwrap() < v1.disk_bytes().unwrap(),
                "shards={shards}: v2 must be smaller on disk"
            );
            let _ = std::fs::remove_dir_all(&dir1);
            let _ = std::fs::remove_dir_all(&dir2);
        }
    }

    #[test]
    fn mixed_format_directory_reads_per_shard_magic() {
        // A partially-recompressed directory: shard 0 rewritten as v2,
        // shard 1 still v1; one cursor must read both.
        let g = sample();
        let dir = temp_dir("mixed");
        let store = write_sharded_as(&g, &dir, 2, ShardFormat::V1).unwrap();
        let (lo, hi) = store.shard_span(0);
        let mut degrees: Vec<u64> = Vec::new();
        let mut arcs: Vec<(NodeId, Weight)> = Vec::new();
        for v in lo..hi {
            degrees.push(g.degree(v as NodeId) as u64);
            for (u, w) in g.neighbors(v as NodeId) {
                arcs.push((u, w));
            }
        }
        write_shard_file_v2(&dir, 0, lo, hi, &degrees, &arcs).unwrap();
        assert_eq!(ShardedStore::open(&dir).unwrap().to_graph().unwrap(), g);
    }

    #[test]
    fn recompress_preserves_graph_and_fingerprints() {
        use crate::graph::store::store_fingerprints;
        let g = sample();
        let src = temp_dir("rc-src");
        let v1 = write_sharded_as(&g, &src, 3, ShardFormat::V1).unwrap();
        let fp = store_fingerprints(&v1).unwrap();
        // v1 → v2, re-sharded.
        let dst = temp_dir("rc-dst");
        let v2 = recompress_store(&src, &dst, Some(5), ShardFormat::V2).unwrap();
        assert_eq!(v2.format(), ShardFormat::V2);
        assert_eq!(v2.num_shards(), 5);
        assert_eq!(v2.to_graph().unwrap(), g);
        assert_eq!(store_fingerprints(&v2).unwrap(), fp);
        // v2 → v1, default shard count carries over.
        let back = temp_dir("rc-back");
        let rt = recompress_store(&dst, &back, None, ShardFormat::V1).unwrap();
        assert_eq!(rt.format(), ShardFormat::V1);
        assert_eq!(rt.num_shards(), 5);
        assert_eq!(rt.to_graph().unwrap(), g);
        assert_eq!(store_fingerprints(&rt).unwrap(), fp);
        // Same directory refused.
        let err = recompress_store(&src, &src, None, ShardFormat::V2).unwrap_err();
        assert!(err.to_string().contains("differ"), "{err}");
        for d in [&src, &dst, &back] {
            let _ = std::fs::remove_dir_all(d);
        }
    }

    #[test]
    fn meta_stamp_tracks_version_and_content() {
        let g = sample();
        let d1 = temp_dir("stamp1");
        let d2 = temp_dir("stamp2");
        write_sharded_as(&g, &d1, 2, ShardFormat::V1).unwrap();
        write_sharded_as(&g, &d2, 2, ShardFormat::V2).unwrap();
        let s1 = meta_stamp(&d1).unwrap();
        let s2 = meta_stamp(&d2).unwrap();
        assert_eq!(s1.format_version(), 1);
        assert_eq!(s2.format_version(), 2);
        // meta.bin differs only in the version field: equal length,
        // different content hash — exactly what (len, mtime) missed.
        assert_ne!(s1, s2);
        assert_ne!(s1.content_fnv(), s2.content_fnv());
        assert_eq!(meta_stamp(&d1).unwrap(), s1, "stamp must be reproducible");
        assert!(meta_stamp(Path::new("/definitely/not/here")).is_err());
        let _ = std::fs::remove_dir_all(&d1);
        let _ = std::fs::remove_dir_all(&d2);
    }

    #[test]
    fn streaming_cut_agrees_with_direct() {
        let g = sample();
        let labels: Vec<u32> = (0..g.n() as u32).map(|v| v % 3).collect();
        let direct = crate::partitioning::metrics::cut_value(&g, &labels);
        let dir = temp_dir("cut");
        let store = write_sharded(&g, &dir, 3).unwrap();
        assert_eq!(streaming_cut(&store, &labels).unwrap(), direct);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn converter_rejects_asymmetric_adjacency() {
        // Node 1 lists 2, but node 2 does not list 1: read_metis would
        // symmetrize, the converter must refuse instead of silently
        // diverging from the in-memory backend.
        let dir = temp_dir("asym");
        let err = convert_metis_to_shards(Cursor::new("3 1\n2\n\n\n"), &dir, 2).unwrap_err();
        assert!(err.to_string().contains("asymmetric"), "{err}");
        // Direction-dependent weights are asymmetry too (fmt=1).
        let dir2 = temp_dir("asym-w");
        let err = convert_metis_to_shards(Cursor::new("2 1 1\n2 5\n1 7\n"), &dir2, 1)
            .unwrap_err();
        assert!(err.to_string().contains("asymmetric"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&dir2);
    }

    #[test]
    fn open_rejects_corruption() {
        let dir = temp_dir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("meta.bin"), b"WRONGMAGIC______").unwrap();
        assert!(ShardedStore::open(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
        assert!(ShardedStore::open(Path::new("/definitely/not/here")).is_err());
    }
}
