//! Sharded graph storage — topology access abstracted over *where the
//! adjacency lives*, so the partitioning pipeline can run on instances
//! whose CSR does not fit in RAM (the paper's headline 3.3G-edge regime;
//! semi-external model after arXiv 1404.4887: node state stays resident,
//! adjacency is streamed).
//!
//! # Model
//!
//! A [`GraphStore`] splits the node range `0..n` into `num_shards`
//! **contiguous** shards; shard `s` owns nodes
//! `shard_span(s) = [lo, hi)` and their outgoing arcs. Node state (node
//! weights — and, in the algorithms on top, labels and cluster-size
//! tables) is always resident: O(n) memory. Adjacency is only reachable
//! through a [`ShardCursor`], which keeps **at most one shard's CSR
//! resident at a time**: `load(s)` replaces the previous shard and
//! returns a [`ShardView`] window onto it. Algorithms that stream
//! shards in increasing order therefore touch each shard file exactly
//! once per pass.
//!
//! Two implementations:
//! - [`InMemoryStore`] — zero-copy windows onto an existing [`Graph`]
//!   (any virtual shard count; `load` never copies or allocates);
//! - [`ShardedStore`] — an on-disk shard directory (format below); the
//!   cursor reuses three grow-only buffers across `load` calls, so the
//!   steady state is allocation-free and peak memory is one shard.
//!
//! The determinism contract extends over storage: every algorithm in
//! this crate that consumes a `GraphStore` (`clustering::external_lpa`,
//! `coarsening::contract::contract_store`,
//! `partitioning::external::partition_store`) is **shard-count- and
//! thread-count-invariant** — same seed + same config ⇒ byte-identical
//! output for any backend, any shard count, any pool size (enforced by
//! `rust/tests/sharded_store.rs`). Sharding is an execution knob, never
//! an algorithmic one.
//!
//! # On-disk shard format (version 1)
//!
//! A store is a directory. All integers are little-endian `u64` (the
//! convention of `graph::io::write_binary`); the format is versioned
//! independently of the single-file `SCLAPG1` dump so the two can
//! evolve separately.
//!
//! `meta.bin` — resident node state + shard table:
//!
//! ```text
//! magic   8 bytes  b"SCLAPM1\0"
//! version u64      SHARD_FORMAT_VERSION (1)
//! n       u64      node count (must fit u32: NodeId)
//! arcs    u64      total directed arc count (2m)
//! shards  u64      shard count S
//! bounds  (S+1)×u64  shard boundaries; bounds[0]=0, bounds[S]=n,
//!                    monotonically non-decreasing (empty shards legal)
//! nodew   n×u64    node weights
//! ```
//!
//! `shard_<s>.bin` — one CSR segment per shard:
//!
//! ```text
//! magic   8 bytes  b"SCLAPS1\0"
//! version u64      SHARD_FORMAT_VERSION (1)
//! lo, hi  u64×2    node span (must match meta bounds)
//! arcs    u64      arc count of this shard
//! deg     (hi-lo)×u64   degrees (prefix-summed into xadj on load)
//! arcs    arcs×(u64 target, u64 weight)  targets are *global* node
//!                                        ids; weights in 1..=i64::MAX
//! ```
//!
//! Arc lists are stored per node sorted by target with duplicates
//! merged — the canonical [`GraphBuilder`](crate::graph::builder)
//! adjacency form — so a `ShardedStore` of a METIS file and the
//! in-memory `read_metis` graph are arc-for-arc identical.

pub mod in_memory;
pub mod sharded;

pub use in_memory::InMemoryStore;
pub use sharded::{convert_metis_to_shards, write_sharded, ShardedStore};

use crate::graph::csr::{EdgeId, Graph, NodeId, Weight};
use std::io;

/// Shard binary format version (meta + shard files).
pub const SHARD_FORMAT_VERSION: u64 = 1;

/// Abstract topology access: counts + resident node state + per-shard
/// adjacency streaming. Object safe — the pipeline takes
/// `&dyn GraphStore`. `Sync` is a supertrait so a store can be shared
/// across pool workers (each worker opens its own [`ShardCursor`];
/// repetition fan-out and future parallel shard prefetch rely on it).
pub trait GraphStore: Sync {
    /// Number of nodes.
    fn n(&self) -> usize;
    /// Number of directed arcs (2m).
    fn arc_count(&self) -> usize;
    /// Number of undirected edges.
    fn m(&self) -> usize {
        self.arc_count() / 2
    }
    fn total_node_weight(&self) -> Weight;
    fn max_node_weight(&self) -> Weight;
    /// Resident node weights, length `n` (the semi-external model keeps
    /// all node state in RAM).
    fn node_weights(&self) -> &[Weight];
    /// Number of contiguous node-range shards.
    fn num_shards(&self) -> usize;
    /// Node span `[lo, hi)` of shard `shard`.
    fn shard_span(&self, shard: usize) -> (usize, usize);
    /// A fresh cursor; see [`ShardCursor`].
    fn cursor(&self) -> Box<dyn ShardCursor + '_>;
    /// Bytes the full CSR would occupy in RAM
    /// ([`crate::graph::csr::csr_footprint_bytes`]) — the quantity the
    /// memory-budget switch compares, available *without* materializing.
    fn memory_bytes(&self) -> u64;
    /// The already-materialized graph, when this backend holds one
    /// (in-memory stores). Lets the budget-fits path run without the
    /// [`to_graph`](GraphStore::to_graph) copy — which would double
    /// peak memory exactly when a budget was asked for.
    fn as_graph(&self) -> Option<&Graph> {
        None
    }
    /// Materialize the full in-memory [`Graph`] (streams every shard).
    fn to_graph(&self) -> io::Result<Graph>;
}

/// Streaming access to one shard at a time. `load(s)` makes shard `s`
/// the resident shard (dropping the previous one) and returns a view;
/// loading the already-resident shard is free. Implementations reuse
/// their buffers across loads — after warm-up, `load` performs no
/// allocation and holds at most one shard's CSR.
pub trait ShardCursor {
    fn load(&mut self, shard: usize) -> io::Result<ShardView<'_>>;
}

/// Borrowed CSR window over one shard's node span `[lo, hi)`.
///
/// `xadj` has length `hi - lo + 1`; offsets are relative to `xadj[0]`
/// (global offsets from an in-memory graph and rebased-to-0 offsets
/// from a shard file both satisfy this), `targets`/`weights` hold
/// exactly this shard's arcs. Targets are global node ids.
#[derive(Debug, Clone, Copy)]
pub struct ShardView<'a> {
    lo: usize,
    hi: usize,
    xadj: &'a [EdgeId],
    targets: &'a [NodeId],
    weights: &'a [Weight],
}

impl<'a> ShardView<'a> {
    pub fn new(
        lo: usize,
        hi: usize,
        xadj: &'a [EdgeId],
        targets: &'a [NodeId],
        weights: &'a [Weight],
    ) -> Self {
        debug_assert_eq!(xadj.len(), hi - lo + 1);
        debug_assert_eq!(xadj[hi - lo] - xadj[0], targets.len());
        debug_assert_eq!(targets.len(), weights.len());
        ShardView {
            lo,
            hi,
            xadj,
            targets,
            weights,
        }
    }

    /// Node span `[lo, hi)` of this view.
    #[inline]
    pub fn span(&self) -> (usize, usize) {
        (self.lo, self.hi)
    }

    /// Arcs in this shard.
    #[inline]
    pub fn arc_count(&self) -> usize {
        self.targets.len()
    }

    #[inline]
    pub fn degree(&self, v: NodeId) -> usize {
        let i = v as usize - self.lo;
        self.xadj[i + 1] - self.xadj[i]
    }

    /// Neighbor ids and aligned edge weights of `v` (global ids).
    #[inline]
    pub fn adjacent(&self, v: NodeId) -> (&'a [NodeId], &'a [Weight]) {
        let i = v as usize - self.lo;
        let base = self.xadj[0];
        let a = self.xadj[i] - base;
        let b = self.xadj[i + 1] - base;
        (&self.targets[a..b], &self.weights[a..b])
    }
}

/// Contiguous shard boundaries for `n` nodes split into `shards` parts
/// (balanced by node count; `shards > n` yields empty trailing spans,
/// which every consumer tolerates).
pub fn shard_bounds(n: usize, shards: usize) -> Vec<usize> {
    let s = shards.max(1);
    (0..=s).map(|i| i * n / s).collect()
}

/// Total weight of cut edges of a labelling, computed in one streaming
/// pass over the shards (each arc read once; labels resident).
pub fn streaming_cut(store: &dyn GraphStore, labels: &[u32]) -> io::Result<Weight> {
    assert_eq!(labels.len(), store.n());
    let mut cut: Weight = 0;
    let mut cursor = store.cursor();
    for s in 0..store.num_shards() {
        let view = cursor.load(s)?;
        let (lo, hi) = view.span();
        for v in lo..hi {
            let bv = labels[v];
            let (adj, ws) = view.adjacent(v as NodeId);
            for (&u, &w) in adj.iter().zip(ws) {
                if labels[u as usize] != bv {
                    cut += w;
                }
            }
        }
    }
    Ok(cut / 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_bounds_cover_and_balance() {
        let b = shard_bounds(10, 3);
        assert_eq!(b.first(), Some(&0));
        assert_eq!(b.last(), Some(&10));
        assert!(b.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(shard_bounds(5, 1), vec![0, 5]);
        // more shards than nodes: empty spans, still covering
        let tiny = shard_bounds(2, 5);
        assert_eq!(tiny.len(), 6);
        assert_eq!(*tiny.last().unwrap(), 2);
        assert_eq!(shard_bounds(0, 4), vec![0, 0, 0, 0, 0]);
    }

    #[test]
    fn shard_view_windows() {
        // Hand-built window: nodes 2..4 of some graph, global offsets.
        let xadj = [10usize, 12, 15];
        let targets = [1u32, 3, 0, 1, 4];
        let weights = [1i64, 2, 3, 4, 5];
        let v = ShardView::new(2, 4, &xadj, &targets, &weights);
        assert_eq!(v.span(), (2, 4));
        assert_eq!(v.arc_count(), 5);
        assert_eq!(v.degree(2), 2);
        assert_eq!(v.degree(3), 3);
        assert_eq!(v.adjacent(2), (&targets[0..2], &weights[0..2]));
        assert_eq!(v.adjacent(3), (&targets[2..5], &weights[2..5]));
    }
}
