//! Sharded graph storage — topology access abstracted over *where the
//! adjacency lives*, so the partitioning pipeline can run on instances
//! whose CSR does not fit in RAM (the paper's headline 3.3G-edge regime;
//! semi-external model after arXiv 1404.4887: node state stays resident,
//! adjacency is streamed).
//!
//! # Model
//!
//! A [`GraphStore`] splits the node range `0..n` into `num_shards`
//! **contiguous** shards; shard `s` owns nodes
//! `shard_span(s) = [lo, hi)` and their outgoing arcs. Node state (node
//! weights — and, in the algorithms on top, labels and cluster-size
//! tables) is always resident: O(n) memory. Adjacency is only reachable
//! through a [`ShardCursor`], which keeps **at most one shard's CSR
//! resident at a time**: `load(s)` replaces the previous shard and
//! returns a [`ShardView`] window onto it. Algorithms that stream
//! shards in increasing order therefore touch each shard file exactly
//! once per pass.
//!
//! Two implementations:
//! - [`InMemoryStore`] — zero-copy windows onto an existing [`Graph`]
//!   (any virtual shard count; `load` never copies or allocates);
//! - [`ShardedStore`] — an on-disk shard directory (format below); the
//!   cursor reuses three grow-only buffers across `load` calls, so the
//!   steady state is allocation-free and peak memory is one shard.
//!
//! The determinism contract extends over storage: every algorithm in
//! this crate that consumes a `GraphStore` (`clustering::external_lpa`,
//! `coarsening::contract::contract_store`,
//! `partitioning::external::partition_store`) is **shard-count- and
//! thread-count-invariant** — same seed + same config ⇒ byte-identical
//! output for any backend, any shard count, any pool size (enforced by
//! `rust/tests/sharded_store.rs`). Sharding is an execution knob, never
//! an algorithmic one.
//!
//! # On-disk shard format (version 1)
//!
//! A store is a directory. All integers are little-endian `u64` (the
//! convention of `graph::io::write_binary`); the format is versioned
//! independently of the single-file `SCLAPG1` dump so the two can
//! evolve separately.
//!
//! `meta.bin` — resident node state + shard table:
//!
//! ```text
//! magic   8 bytes  b"SCLAPM1\0"
//! version u64      SHARD_FORMAT_VERSION (1)
//! n       u64      node count (must fit u32: NodeId)
//! arcs    u64      total directed arc count (2m)
//! shards  u64      shard count S
//! bounds  (S+1)×u64  shard boundaries; bounds[0]=0, bounds[S]=n,
//!                    monotonically non-decreasing (empty shards legal)
//! nodew   n×u64    node weights
//! ```
//!
//! `shard_<s>.bin` — one CSR segment per shard:
//!
//! ```text
//! magic   8 bytes  b"SCLAPS1\0"
//! version u64      SHARD_FORMAT_VERSION (1)
//! lo, hi  u64×2    node span (must match meta bounds)
//! arcs    u64      arc count of this shard
//! deg     (hi-lo)×u64   degrees (prefix-summed into xadj on load)
//! arcs    arcs×(u64 target, u64 weight)  targets are *global* node
//!                                        ids; weights in 1..=i64::MAX
//! ```
//!
//! Arc lists are stored per node sorted by target with duplicates
//! merged — the canonical [`GraphBuilder`](crate::graph::builder)
//! adjacency form — so a `ShardedStore` of a METIS file and the
//! in-memory `read_metis` graph are arc-for-arc identical.
//!
//! # On-disk shard format (version 2, `SCLAPS2`)
//!
//! The compressed shard format (the semi-external pipeline is I/O
//! bound — arXiv 1404.4887 — so fewer bytes per arc buys wall-clock
//! directly). `meta.bin` keeps the **identical** layout, with
//! `version = 2`; only the shard files change:
//!
//! ```text
//! magic       8 bytes  b"SCLAPS2\0"
//! version     u64      2
//! lo, hi      u64×2    node span (must match meta bounds)
//! arcs        u64      arc count of this shard
//! block_nodes u64      nodes per index block (BLOCK_NODES, > 0)
//! nblocks     u64      ceil((hi-lo) / block_nodes)
//! payload_len u64      compressed payload bytes
//! index       nblocks×(u64 payload offset, u64 arc start)
//!                      entry b locates node lo + b*block_nodes;
//!                      entry 0 is (0, 0); strictly monotone
//! payload     payload_len bytes, per node lo..hi:
//!               varint  degree d
//!               varint  zigzag(t[0] − v)           (if d > 0)
//!               varint  t[i] − t[i−1] − 1           (i in 1..d)
//!               varint  w[0]
//!               varint  zigzag(w[i] − w[i−1])       (i in 1..d)
//! ```
//!
//! All varints are canonical LEB128 (`graph::store::codec`); targets
//! are global node ids, strictly ascending per node; weights in
//! `1..=i64::MAX`. The block index lets a future cursor start decoding
//! at any 1024-node boundary without scanning from `lo`; today's
//! sequential cursor checks each index entry against the running
//! decode position, so a lying index is an `InvalidData` error, not a
//! wrong answer. Streaming stays O(resident shard): the cursor holds
//! one shard's payload + decoded CSR, nothing else.
//!
//! **Compatibility guarantee:** version 1 files remain readable
//! forever — the cursor auto-detects the format per shard file from
//! the magic, so v1 and v2 shards (even mixed in one directory, as a
//! partially-recompressed store would be) read through the same
//! [`ShardCursor`] API, and [`store_fingerprints`] hashes the logical
//! CSR stream, so a graph fingerprints identically in either format
//! (v1 and v2 of one graph share a `net::cache` entry).
//!
//! **Which format to write:** v2 (the CLI default) — typically 3-5×
//! smaller on disk and ~1.5-2× faster to stream-decode than v1's raw
//! 16-bytes-per-arc layout; decode cost is a handful of shifts per
//! arc, far below the saved I/O. Prefer v1 only when bytes must be
//! mmap-able or inspected as plain `u64`s (debugging, external
//! tooling). `shard recompress` converts a directory either way.

pub mod codec;
pub mod in_memory;
pub mod sharded;

pub use in_memory::InMemoryStore;
pub use sharded::{
    convert_metis_to_shards, convert_metis_to_shards_as, meta_stamp, recompress_store,
    write_sharded, write_sharded_as, MetaStamp, ShardedStore,
};

use crate::graph::csr::{EdgeId, Graph, NodeId, Weight};
use std::io;

/// Shard binary format version (meta + shard files) written by the
/// plain [`write_sharded`] / [`convert_metis_to_shards`] entry points;
/// the highest *readable* version is [`ShardFormat::V2`].
pub const SHARD_FORMAT_VERSION: u64 = 1;

/// On-disk shard format selector (module docs describe both layouts).
/// Reading never needs one — the magic in each file decides — writing
/// does.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ShardFormat {
    /// Raw little-endian `u64` CSR segments (`SCLAPS1`).
    V1,
    /// Delta + canonical-LEB128-varint compressed segments with a
    /// block index (`SCLAPS2`).
    V2,
}

impl ShardFormat {
    /// Both formats, oldest first (bench/test sweep axis).
    pub const ALL: [ShardFormat; 2] = [ShardFormat::V1, ShardFormat::V2];

    /// The `version` field written to `meta.bin` and shard headers.
    pub fn version(self) -> u64 {
        match self {
            ShardFormat::V1 => 1,
            ShardFormat::V2 => 2,
        }
    }

    /// Format for a header version, if supported.
    pub fn from_version(version: u64) -> Option<ShardFormat> {
        match version {
            1 => Some(ShardFormat::V1),
            2 => Some(ShardFormat::V2),
            _ => None,
        }
    }

    /// Parse a CLI spelling (`v1`/`1`/`v2`/`2`).
    pub fn parse(s: &str) -> Option<ShardFormat> {
        match s {
            "v1" | "V1" | "1" => Some(ShardFormat::V1),
            "v2" | "V2" | "2" => Some(ShardFormat::V2),
            _ => None,
        }
    }

    /// Stable lower-case name (`"v1"` / `"v2"`) for reports and logs.
    pub fn name(self) -> &'static str {
        match self {
            ShardFormat::V1 => "v1",
            ShardFormat::V2 => "v2",
        }
    }
}

/// Abstract topology access: counts + resident node state + per-shard
/// adjacency streaming. Object safe — the pipeline takes
/// `&dyn GraphStore`. `Sync` is a supertrait so a store can be shared
/// across pool workers (each worker opens its own [`ShardCursor`];
/// repetition fan-out and future parallel shard prefetch rely on it).
pub trait GraphStore: Sync {
    /// Number of nodes.
    fn n(&self) -> usize;
    /// Number of directed arcs (2m).
    fn arc_count(&self) -> usize;
    /// Number of undirected edges.
    fn m(&self) -> usize {
        self.arc_count() / 2
    }
    fn total_node_weight(&self) -> Weight;
    fn max_node_weight(&self) -> Weight;
    /// Resident node weights, length `n` (the semi-external model keeps
    /// all node state in RAM).
    fn node_weights(&self) -> &[Weight];
    /// Number of contiguous node-range shards.
    fn num_shards(&self) -> usize;
    /// Node span `[lo, hi)` of shard `shard`.
    fn shard_span(&self, shard: usize) -> (usize, usize);
    /// A fresh cursor; see [`ShardCursor`].
    fn cursor(&self) -> Box<dyn ShardCursor + '_>;
    /// Bytes the full CSR would occupy in RAM
    /// ([`crate::graph::csr::csr_footprint_bytes`]) — the quantity the
    /// memory-budget switch compares, available *without* materializing.
    fn memory_bytes(&self) -> u64;
    /// The already-materialized graph, when this backend holds one
    /// (in-memory stores). Lets the budget-fits path run without the
    /// [`to_graph`](GraphStore::to_graph) copy — which would double
    /// peak memory exactly when a budget was asked for.
    fn as_graph(&self) -> Option<&Graph> {
        None
    }
    /// Materialize the full in-memory [`Graph`] (streams every shard).
    fn to_graph(&self) -> io::Result<Graph>;
}

/// Streaming access to one shard at a time. `load(s)` makes shard `s`
/// the resident shard (dropping the previous one) and returns a view;
/// loading the already-resident shard is free. Implementations reuse
/// their buffers across loads — after warm-up, `load` performs no
/// allocation and holds at most one shard's CSR.
pub trait ShardCursor {
    fn load(&mut self, shard: usize) -> io::Result<ShardView<'_>>;
}

/// Borrowed CSR window over one shard's node span `[lo, hi)`.
///
/// `xadj` has length `hi - lo + 1`; offsets are relative to `xadj[0]`
/// (global offsets from an in-memory graph and rebased-to-0 offsets
/// from a shard file both satisfy this), `targets`/`weights` hold
/// exactly this shard's arcs. Targets are global node ids.
#[derive(Debug, Clone, Copy)]
pub struct ShardView<'a> {
    lo: usize,
    hi: usize,
    xadj: &'a [EdgeId],
    targets: &'a [NodeId],
    weights: &'a [Weight],
}

impl<'a> ShardView<'a> {
    pub fn new(
        lo: usize,
        hi: usize,
        xadj: &'a [EdgeId],
        targets: &'a [NodeId],
        weights: &'a [Weight],
    ) -> Self {
        debug_assert_eq!(xadj.len(), hi - lo + 1);
        debug_assert_eq!(xadj[hi - lo] - xadj[0], targets.len());
        debug_assert_eq!(targets.len(), weights.len());
        ShardView {
            lo,
            hi,
            xadj,
            targets,
            weights,
        }
    }

    /// Node span `[lo, hi)` of this view.
    #[inline]
    pub fn span(&self) -> (usize, usize) {
        (self.lo, self.hi)
    }

    /// Arcs in this shard.
    #[inline]
    pub fn arc_count(&self) -> usize {
        self.targets.len()
    }

    #[inline]
    pub fn degree(&self, v: NodeId) -> usize {
        let i = v as usize - self.lo;
        self.xadj[i + 1] - self.xadj[i]
    }

    /// Neighbor ids and aligned edge weights of `v` (global ids).
    #[inline]
    pub fn adjacent(&self, v: NodeId) -> (&'a [NodeId], &'a [Weight]) {
        let i = v as usize - self.lo;
        let base = self.xadj[0];
        let a = self.xadj[i] - base;
        let b = self.xadj[i + 1] - base;
        (&self.targets[a..b], &self.weights[a..b])
    }
}

/// Contiguous shard boundaries for `n` nodes split into `shards` parts
/// (balanced by node count; `shards > n` yields empty trailing spans,
/// which every consumer tolerates).
pub fn shard_bounds(n: usize, shards: usize) -> Vec<usize> {
    let s = shards.max(1);
    (0..=s).map(|i| i * n / s).collect()
}

/// FNV-1a 64 offset basis / prime (the crate-wide fingerprint hash —
/// same constants as `coordinator::queue::spec::blocks_fingerprint`).
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x100_0000_01b3;

#[inline]
fn fnv_u64(h: u64, x: u64) -> u64 {
    let mut h = h;
    for byte in x.to_le_bytes() {
        h ^= byte as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a 64 over a raw byte slice (the [`MetaStamp`] content hash).
pub(crate) fn fnv1a_bytes(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Content fingerprint of a stored graph: a **pair** of independent
/// 64-bit hashes over the logical CSR stream — `n`, `arc_count`, then
/// per node its weight and degree, then the node's arcs as
/// `(target, weight)` pairs — every value as a little-endian `u64`.
/// The first hash is FNV-1a 64; the second chains
/// [`splitmix64`](crate::util::rng::splitmix64) over the same stream,
/// so a crafted or accidental collision must defeat both mixers on the
/// identical value sequence (~2^128 work, vs ~2^32 birthday pairs for
/// one 64-bit hash on a long-lived server).
///
/// Because shards are contiguous node ranges streamed in increasing
/// order, the stream (and hence the pair) is **invariant to the shard
/// count and the storage backend**: the same topology fingerprints
/// identically as an [`InMemoryStore`] or as any [`ShardedStore`]
/// layout, without ever materializing the graph (O(1) topology state
/// beyond one shard). One streaming pass computes both halves.
///
/// This is the graph half of the service layer's content-addressed
/// cache key (`coordinator::net::cache`): two requests hit the same
/// cache entry exactly when their topologies are arc-for-arc equal.
pub fn store_fingerprints(store: &dyn GraphStore) -> io::Result<(u64, u64)> {
    let mut h = FNV_OFFSET;
    let mut h2: u64 = 0x5CA1_AB1E_0DD5_EED5;
    let mix = |h: &mut u64, h2: &mut u64, x: u64| {
        *h = fnv_u64(*h, x);
        *h2 = crate::util::rng::splitmix64(*h2 ^ x);
    };
    mix(&mut h, &mut h2, store.n() as u64);
    mix(&mut h, &mut h2, store.arc_count() as u64);
    let weights = store.node_weights();
    let mut cursor = store.cursor();
    for s in 0..store.num_shards() {
        let view = cursor.load(s)?;
        let (lo, hi) = view.span();
        for v in lo..hi {
            mix(&mut h, &mut h2, weights[v] as u64);
            let (adj, ws) = view.adjacent(v as NodeId);
            mix(&mut h, &mut h2, adj.len() as u64);
            for (&u, &w) in adj.iter().zip(ws) {
                mix(&mut h, &mut h2, u as u64);
                mix(&mut h, &mut h2, w as u64);
            }
        }
    }
    Ok((h, h2))
}

/// The FNV-1a half of [`store_fingerprints`], for callers that want a
/// single compact value (reports, logs).
pub fn store_fingerprint(store: &dyn GraphStore) -> io::Result<u64> {
    store_fingerprints(store).map(|(h, _)| h)
}

/// [`store_fingerprints`] of an in-memory graph (zero-copy view).
pub fn graph_fingerprint(graph: &Graph) -> u64 {
    store_fingerprint(&InMemoryStore::new(graph)).expect("in-memory fingerprint cannot fail")
}

/// Total weight of cut edges of a labelling, computed in one streaming
/// pass over the shards (each arc read once; labels resident).
pub fn streaming_cut(store: &dyn GraphStore, labels: &[u32]) -> io::Result<Weight> {
    assert_eq!(labels.len(), store.n());
    let mut cut: Weight = 0;
    let mut cursor = store.cursor();
    for s in 0..store.num_shards() {
        let view = cursor.load(s)?;
        let (lo, hi) = view.span();
        for v in lo..hi {
            let bv = labels[v];
            let (adj, ws) = view.adjacent(v as NodeId);
            for (&u, &w) in adj.iter().zip(ws) {
                if labels[u as usize] != bv {
                    cut += w;
                }
            }
        }
    }
    Ok(cut / 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_bounds_cover_and_balance() {
        let b = shard_bounds(10, 3);
        assert_eq!(b.first(), Some(&0));
        assert_eq!(b.last(), Some(&10));
        assert!(b.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(shard_bounds(5, 1), vec![0, 5]);
        // more shards than nodes: empty spans, still covering
        let tiny = shard_bounds(2, 5);
        assert_eq!(tiny.len(), 6);
        assert_eq!(*tiny.last().unwrap(), 2);
        assert_eq!(shard_bounds(0, 4), vec![0, 0, 0, 0, 0]);
    }

    #[test]
    fn fingerprint_is_backend_and_shard_count_invariant() {
        let g = crate::graph::karate_club();
        let reference = graph_fingerprint(&g);
        let pair = store_fingerprints(&InMemoryStore::new(&g)).unwrap();
        assert_eq!(pair.0, reference, "first half is the FNV hash");
        assert_ne!(pair.0, pair.1, "halves are independent mixers");
        for shards in [1usize, 2, 3, 7, 50] {
            let mem = InMemoryStore::with_shards(&g, shards);
            assert_eq!(
                store_fingerprints(&mem).unwrap(),
                pair,
                "virtual shard count {shards} changed the fingerprint"
            );
        }
        let dir = std::env::temp_dir().join(format!(
            "sclap-fp-{}-{:x}",
            std::process::id(),
            reference
        ));
        for shards in [1usize, 3] {
            let store = write_sharded(&g, &dir, shards).unwrap();
            assert_eq!(store_fingerprints(&store).unwrap(), pair);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fingerprint_distinguishes_equal_sized_graphs() {
        use crate::graph::builder::GraphBuilder;
        // Same n, same m, different topology: a 6-cycle vs two triangles.
        let mut cycle = GraphBuilder::new(6);
        for v in 0..6u32 {
            cycle.add_edge(v, (v + 1) % 6, 1);
        }
        let mut triangles = GraphBuilder::new(6);
        for base in [0u32, 3] {
            triangles.add_edge(base, base + 1, 1);
            triangles.add_edge(base + 1, base + 2, 1);
            triangles.add_edge(base + 2, base, 1);
        }
        let (a, b) = (cycle.build(), triangles.build());
        assert_eq!((a.n(), a.m()), (b.n(), b.m()));
        assert_ne!(graph_fingerprint(&a), graph_fingerprint(&b));
        // Same topology, different edge weight: distinguished.
        let w1 = GraphBuilder::new(2).edge(0, 1).build();
        let mut w2 = GraphBuilder::new(2);
        w2.add_edge(0, 1, 5);
        assert_ne!(graph_fingerprint(&w1), graph_fingerprint(&w2.build()));
        // Same topology, different node weight: distinguished.
        let nw = GraphBuilder::new(2)
            .node_weights(vec![2, 1])
            .edge(0, 1)
            .build();
        assert_ne!(graph_fingerprint(&w1), graph_fingerprint(&nw));
    }

    #[test]
    fn shard_view_windows() {
        // Hand-built window: nodes 2..4 of some graph, global offsets.
        let xadj = [10usize, 12, 15];
        let targets = [1u32, 3, 0, 1, 4];
        let weights = [1i64, 2, 3, 4, 5];
        let v = ShardView::new(2, 4, &xadj, &targets, &weights);
        assert_eq!(v.span(), (2, 4));
        assert_eq!(v.arc_count(), 5);
        assert_eq!(v.degree(2), 2);
        assert_eq!(v.degree(3), 3);
        assert_eq!(v.adjacent(2), (&targets[0..2], &weights[0..2]));
        assert_eq!(v.adjacent(3), (&targets[2..5], &weights[2..5]));
    }
}
