//! Zachary's karate club — the canonical 34-node social network, embedded
//! as ground-truth test data (the only "real" instance small enough to
//! ship in-tree; everything larger is generated, see DESIGN.md §3).

use super::builder::GraphBuilder;
use super::csr::Graph;

/// The 78 undirected edges of Zachary's karate club (0-indexed).
pub const KARATE_EDGES: [(u32, u32); 78] = [
    (0, 1), (0, 2), (0, 3), (0, 4), (0, 5), (0, 6), (0, 7), (0, 8),
    (0, 10), (0, 11), (0, 12), (0, 13), (0, 17), (0, 19), (0, 21), (0, 31),
    (1, 2), (1, 3), (1, 7), (1, 13), (1, 17), (1, 19), (1, 21), (1, 30),
    (2, 3), (2, 7), (2, 8), (2, 9), (2, 13), (2, 27), (2, 28), (2, 32),
    (3, 7), (3, 12), (3, 13), (4, 6), (4, 10), (5, 6), (5, 10), (5, 16),
    (6, 16), (8, 30), (8, 32), (8, 33), (9, 33), (13, 33), (14, 32),
    (14, 33), (15, 32), (15, 33), (18, 32), (18, 33), (19, 33), (20, 32),
    (20, 33), (22, 32), (22, 33), (23, 25), (23, 27), (23, 29), (23, 32),
    (23, 33), (24, 25), (24, 27), (24, 31), (25, 31), (26, 29), (26, 33),
    (27, 33), (28, 31), (28, 33), (29, 32), (29, 33), (30, 32), (30, 33),
    (31, 32), (31, 33), (32, 33),
];

/// The split after the club's real-world fission (Mr. Hi = block 0,
/// Officer = block 1) — a natural 2-partition with cut 10.
pub const KARATE_FACTION: [u32; 34] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 0, 0, 0, 0, 1, 1, 0, 0, 1, 0, 1, 0, 1,
    1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1,
];

/// Build the karate-club graph (34 nodes, 78 edges, unit weights).
pub fn karate_club() -> Graph {
    let mut b = GraphBuilder::new(34);
    for &(u, v) in KARATE_EDGES.iter() {
        b.add_edge(u, v, 1);
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn karate_shape() {
        let g = karate_club();
        assert_eq!(g.n(), 34);
        assert_eq!(g.m(), 78);
        assert!(g.validate().is_ok());
        // Node 33 (the officer) and node 0 (Mr. Hi) are the hubs.
        assert_eq!(g.degree(33), 17);
        assert_eq!(g.degree(0), 16);
    }

    #[test]
    fn faction_split_cut_is_ten() {
        let g = karate_club();
        let cut: i64 = g
            .edges()
            .filter(|&(u, v, _)| KARATE_FACTION[u as usize] != KARATE_FACTION[v as usize])
            .map(|(_, _, w)| w)
            .sum();
        assert_eq!(cut, 10);
    }

    #[test]
    fn faction_is_roughly_balanced() {
        // Zachary's observed fission is a 16/18 split (node 8 sided with
        // the officer's club despite supporting Mr. Hi).
        let ones = KARATE_FACTION.iter().filter(|&&f| f == 1).count();
        assert_eq!(ones, 18);
    }
}
