//! Compressed sparse row graph — the core substrate.
//!
//! Matches the paper's model (§2.1): undirected graph
//! `G = (V, E, c, ω)` with node weights `c : V → ℝ≥0` and edge weights
//! `ω : E → ℝ>0`. We store integer weights (i64) — the paper's inputs
//! are unit-weighted and contraction sums weights, so integers are exact
//! and cut values are exactly comparable across levels.
//!
//! Each undirected edge {u,v} is stored twice (u→v and v→u), the usual
//! METIS convention; `m()` reports the number of *undirected* edges.

pub type NodeId = u32;
pub type EdgeId = usize;
pub type Weight = i64;

/// Exact heap footprint (bytes) of the CSR component arrays of a graph
/// with `n` nodes and `arcs` directed arcs: `xadj` (n+1 × EdgeId),
/// `node_weights` (n × Weight), `targets` (arcs × NodeId) and
/// `edge_weights` (arcs × Weight). The single size formula shared by
/// [`Graph::memory_bytes`] and the `graph::store` backends, so the
/// in-memory/out-of-core switch decision can be made *before* a graph
/// is materialized.
pub fn csr_footprint_bytes(n: usize, arcs: usize) -> u64 {
    let per_node = std::mem::size_of::<Weight>() as u64;
    let xadj = (n as u64 + 1) * std::mem::size_of::<EdgeId>() as u64;
    let per_arc = (std::mem::size_of::<NodeId>() + std::mem::size_of::<Weight>()) as u64;
    xadj + n as u64 * per_node + arcs as u64 * per_arc
}

/// Immutable CSR graph with node and edge weights.
#[derive(Debug, Clone, PartialEq)]
pub struct Graph {
    /// offsets into `targets`/`edge_weights`, length n+1
    xadj: Vec<EdgeId>,
    targets: Vec<NodeId>,
    edge_weights: Vec<Weight>,
    node_weights: Vec<Weight>,
    total_node_weight: Weight,
    total_edge_weight: Weight,
}

impl Graph {
    /// Construct from raw CSR arrays. Panics (debug) on malformed input;
    /// use [`crate::graph::builder::GraphBuilder`] for edge-list input.
    pub fn from_csr(
        xadj: Vec<EdgeId>,
        targets: Vec<NodeId>,
        edge_weights: Vec<Weight>,
        node_weights: Vec<Weight>,
    ) -> Self {
        assert_eq!(xadj.len(), node_weights.len() + 1);
        assert_eq!(*xadj.last().unwrap(), targets.len());
        assert_eq!(targets.len(), edge_weights.len());
        let total_node_weight = node_weights.iter().sum();
        let total_edge_weight = edge_weights.iter().sum::<Weight>() / 2;
        Graph {
            xadj,
            targets,
            edge_weights,
            node_weights,
            total_node_weight,
            total_edge_weight,
        }
    }

    /// Number of nodes.
    #[inline]
    pub fn n(&self) -> usize {
        self.node_weights.len()
    }

    /// Number of undirected edges.
    #[inline]
    pub fn m(&self) -> usize {
        self.targets.len() / 2
    }

    /// Number of directed arcs (2m).
    #[inline]
    pub fn arc_count(&self) -> usize {
        self.targets.len()
    }

    #[inline]
    pub fn degree(&self, v: NodeId) -> usize {
        let v = v as usize;
        self.xadj[v + 1] - self.xadj[v]
    }

    /// Sum of incident edge weights (weighted degree).
    #[inline]
    pub fn weighted_degree(&self, v: NodeId) -> Weight {
        let v = v as usize;
        self.edge_weights[self.xadj[v]..self.xadj[v + 1]]
            .iter()
            .sum()
    }

    #[inline]
    pub fn node_weight(&self, v: NodeId) -> Weight {
        self.node_weights[v as usize]
    }

    #[inline]
    pub fn total_node_weight(&self) -> Weight {
        self.total_node_weight
    }

    /// Sum of ω over undirected edges.
    #[inline]
    pub fn total_edge_weight(&self) -> Weight {
        self.total_edge_weight
    }

    /// Maximum node weight (0 for the empty graph).
    pub fn max_node_weight(&self) -> Weight {
        self.node_weights.iter().copied().max().unwrap_or(0)
    }

    pub fn max_degree(&self) -> usize {
        (0..self.n() as NodeId)
            .map(|v| self.degree(v))
            .max()
            .unwrap_or(0)
    }

    /// Exact CSR footprint of this graph in bytes (component arrays
    /// only; `Vec` headers and allocator slack excluded). This is the
    /// number the `graph::store` memory-budget switch compares against
    /// `PartitionConfig::memory_budget_bytes`.
    pub fn memory_bytes(&self) -> u64 {
        csr_footprint_bytes(self.n(), self.arc_count())
    }

    /// Raw CSR components `(xadj, targets, edge_weights)` — the
    /// zero-copy window the in-memory `graph::store` shard views sit
    /// on. `xadj` has length `n + 1` with global arc offsets.
    #[inline]
    pub fn raw_csr(&self) -> (&[EdgeId], &[NodeId], &[Weight]) {
        (&self.xadj, &self.targets, &self.edge_weights)
    }

    /// Neighbors of `v` with edge weights.
    #[inline]
    pub fn neighbors(&self, v: NodeId) -> impl Iterator<Item = (NodeId, Weight)> + '_ {
        let v = v as usize;
        let range = self.xadj[v]..self.xadj[v + 1];
        self.targets[range.clone()]
            .iter()
            .copied()
            .zip(self.edge_weights[range].iter().copied())
    }

    /// Neighbor ids only (slice access — the hot-path form).
    #[inline]
    pub fn adjacent(&self, v: NodeId) -> &[NodeId] {
        let v = v as usize;
        &self.targets[self.xadj[v]..self.xadj[v + 1]]
    }

    /// Edge weights aligned with [`Self::adjacent`].
    #[inline]
    pub fn adjacent_weights(&self, v: NodeId) -> &[Weight] {
        let v = v as usize;
        &self.edge_weights[self.xadj[v]..self.xadj[v + 1]]
    }

    #[inline]
    pub fn nodes(&self) -> impl Iterator<Item = NodeId> {
        0..self.n() as NodeId
    }

    /// All node weights.
    #[inline]
    pub fn node_weights(&self) -> &[Weight] {
        &self.node_weights
    }

    /// Edges as (u, v, w) with u < v (each undirected edge once).
    pub fn edges(&self) -> impl Iterator<Item = (NodeId, NodeId, Weight)> + '_ {
        self.nodes().flat_map(move |u| {
            self.neighbors(u)
                .filter(move |&(v, _)| u < v)
                .map(move |(v, w)| (u, v, w))
        })
    }

    /// Structural validation; returns a description of the first defect.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.n();
        if self.xadj[0] != 0 {
            return Err("xadj[0] != 0".into());
        }
        for v in 0..n {
            if self.xadj[v + 1] < self.xadj[v] {
                return Err(format!("xadj not monotone at {v}"));
            }
        }
        for (i, &t) in self.targets.iter().enumerate() {
            if t as usize >= n {
                return Err(format!("target out of range at arc {i}"));
            }
        }
        for v in 0..n as NodeId {
            for (u, w) in self.neighbors(v) {
                if u == v {
                    return Err(format!("self loop at {v}"));
                }
                if w <= 0 {
                    return Err(format!("non-positive edge weight on ({v},{u})"));
                }
                // Symmetry: u must list v with the same weight.
                let back = self
                    .neighbors(u)
                    .find(|&(x, _)| x == v)
                    .map(|(_, bw)| bw);
                match back {
                    Some(bw) if bw == w => {}
                    Some(bw) => {
                        return Err(format!(
                            "asymmetric weight ({v},{u}): {w} vs {bw}"
                        ))
                    }
                    None => return Err(format!("missing reverse arc ({u},{v})")),
                }
            }
        }
        if self.targets.len() % 2 != 0 {
            return Err("odd arc count".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn triangle() -> Graph {
        GraphBuilder::new(3)
            .edge(0, 1)
            .edge(1, 2)
            .edge(0, 2)
            .build()
    }

    #[test]
    fn basic_counts() {
        let g = triangle();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 3);
        assert_eq!(g.arc_count(), 6);
        assert_eq!(g.total_node_weight(), 3);
        assert_eq!(g.total_edge_weight(), 3);
        for v in g.nodes() {
            assert_eq!(g.degree(v), 2);
            assert_eq!(g.weighted_degree(v), 2);
            assert_eq!(g.node_weight(v), 1);
        }
    }

    #[test]
    fn neighbors_and_edges() {
        let g = triangle();
        let mut nb: Vec<_> = g.adjacent(0).to_vec();
        nb.sort_unstable();
        assert_eq!(nb, vec![1, 2]);
        let edges: Vec<_> = g.edges().collect();
        assert_eq!(edges.len(), 3);
        for (u, v, w) in edges {
            assert!(u < v);
            assert_eq!(w, 1);
        }
    }

    #[test]
    fn validate_ok() {
        assert!(triangle().validate().is_ok());
    }

    #[test]
    fn validate_detects_asymmetry() {
        // Hand-build a broken CSR: arc 0->1 but no 1->0.
        let g = Graph::from_csr(vec![0, 1, 1], vec![1], vec![1], vec![1, 1]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_detects_self_loop() {
        let g = Graph::from_csr(vec![0, 2, 2], vec![0, 0], vec![1, 1], vec![1, 1]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn memory_bytes_matches_component_arrays() {
        let g = triangle();
        let (xadj, targets, weights) = g.raw_csr();
        let expect = (xadj.len() * std::mem::size_of::<EdgeId>()
            + targets.len() * std::mem::size_of::<NodeId>()
            + weights.len() * std::mem::size_of::<Weight>()
            + g.n() * std::mem::size_of::<Weight>()) as u64;
        assert_eq!(g.memory_bytes(), expect);
        assert_eq!(g.memory_bytes(), csr_footprint_bytes(g.n(), g.arc_count()));
        // 64-bit usize/i64, u32 NodeId: 4*8 + 3*8 + 6*4 + 6*8 = 128.
        assert_eq!(g.memory_bytes(), 128);
        // The formula is usable before materialization.
        assert_eq!(csr_footprint_bytes(0, 0), 8);
    }

    #[test]
    fn raw_csr_is_the_adjacency() {
        let g = triangle();
        let (xadj, targets, weights) = g.raw_csr();
        assert_eq!(xadj.len(), g.n() + 1);
        assert_eq!(targets.len(), g.arc_count());
        assert_eq!(weights.len(), g.arc_count());
        for v in g.nodes() {
            assert_eq!(&targets[xadj[v as usize]..xadj[v as usize + 1]], g.adjacent(v));
        }
    }

    #[test]
    fn empty_graph() {
        let g = Graph::from_csr(vec![0], vec![], vec![], vec![]);
        assert_eq!(g.n(), 0);
        assert_eq!(g.m(), 0);
        assert_eq!(g.max_node_weight(), 0);
        assert_eq!(g.max_degree(), 0);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn singleton_graph() {
        let g = Graph::from_csr(vec![0, 0], vec![], vec![], vec![5]);
        assert_eq!(g.n(), 1);
        assert_eq!(g.m(), 0);
        assert_eq!(g.total_node_weight(), 5);
        assert!(g.validate().is_ok());
    }
}
