//! Graph statistics for the instance table (Table 1) and for verifying
//! that generated instances have the structural properties the paper's
//! claims depend on (scale-free degree law, small-world diameter).

use super::csr::{Graph, NodeId};
use crate::util::rng::Rng;
use crate::util::union_find::UnionFind;
use std::collections::VecDeque;

/// Summary statistics of a graph instance.
#[derive(Debug, Clone)]
pub struct GraphStats {
    pub n: usize,
    pub m: usize,
    pub min_degree: usize,
    pub max_degree: usize,
    pub avg_degree: f64,
    pub components: usize,
    /// Gini coefficient of the degree distribution — ~0 for regular
    /// meshes, high (>0.4) for scale-free networks.
    pub degree_gini: f64,
    /// BFS eccentricity from a few random sources (diameter lower bound;
    /// small for small-world graphs).
    pub approx_diameter: usize,
    /// Global clustering coefficient estimated by wedge sampling.
    pub clustering_coeff: f64,
}

pub fn compute_stats(g: &Graph, rng: &mut Rng) -> GraphStats {
    let n = g.n();
    let degrees: Vec<usize> = g.nodes().map(|v| g.degree(v)).collect();
    let min_degree = degrees.iter().copied().min().unwrap_or(0);
    let max_degree = degrees.iter().copied().max().unwrap_or(0);
    let avg_degree = if n == 0 {
        0.0
    } else {
        degrees.iter().sum::<usize>() as f64 / n as f64
    };

    GraphStats {
        n,
        m: g.m(),
        min_degree,
        max_degree,
        avg_degree,
        components: component_count(g),
        degree_gini: gini(&degrees),
        approx_diameter: approx_diameter(g, rng, 4),
        clustering_coeff: sample_clustering(g, rng, 2000),
    }
}

/// Number of connected components.
pub fn component_count(g: &Graph) -> usize {
    let mut uf = UnionFind::new(g.n());
    for (u, v, _) in g.edges() {
        uf.union(u as usize, v as usize);
    }
    uf.component_count()
}

/// Gini coefficient of a non-negative integer distribution.
fn gini(values: &[usize]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<usize> = values.to_vec();
    sorted.sort_unstable();
    let n = sorted.len() as f64;
    let sum: f64 = sorted.iter().map(|&v| v as f64).sum();
    if sum == 0.0 {
        return 0.0;
    }
    let weighted: f64 = sorted
        .iter()
        .enumerate()
        .map(|(i, &v)| (i as f64 + 1.0) * v as f64)
        .sum();
    (2.0 * weighted) / (n * sum) - (n + 1.0) / n
}

/// Max BFS eccentricity over `sources` random start nodes (lower bound
/// on the diameter; for small-world graphs this saturates quickly).
pub fn approx_diameter(g: &Graph, rng: &mut Rng, sources: usize) -> usize {
    if g.n() == 0 {
        return 0;
    }
    let mut best = 0;
    let mut dist = vec![u32::MAX; g.n()];
    let mut queue = VecDeque::new();
    for _ in 0..sources {
        let s = rng.below(g.n()) as NodeId;
        dist.fill(u32::MAX);
        dist[s as usize] = 0;
        queue.clear();
        queue.push_back(s);
        let mut ecc = 0;
        while let Some(v) = queue.pop_front() {
            let d = dist[v as usize];
            ecc = ecc.max(d as usize);
            for &u in g.adjacent(v) {
                if dist[u as usize] == u32::MAX {
                    dist[u as usize] = d + 1;
                    queue.push_back(u);
                }
            }
        }
        best = best.max(ecc);
    }
    best
}

/// Global clustering coefficient (fraction of closed wedges), estimated
/// by sampling `samples` random wedges.
fn sample_clustering(g: &Graph, rng: &mut Rng, samples: usize) -> f64 {
    let candidates: Vec<NodeId> = g.nodes().filter(|&v| g.degree(v) >= 2).collect();
    if candidates.is_empty() {
        return 0.0;
    }
    let mut closed = 0usize;
    for _ in 0..samples {
        let v = *rng.choose(&candidates);
        let adj = g.adjacent(v);
        let i = rng.below(adj.len());
        let mut j = rng.below(adj.len());
        while j == i {
            j = rng.below(adj.len());
        }
        let (a, b) = (adj[i], adj[j]);
        // adjacency arrays are sorted → binary search
        if g.adjacent(a).binary_search(&b).is_ok() {
            closed += 1;
        }
    }
    closed as f64 / samples as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn path_graph(n: usize) -> Graph {
        let mut b = GraphBuilder::new(n);
        for i in 1..n {
            b.add_edge((i - 1) as NodeId, i as NodeId, 1);
        }
        b.build()
    }

    fn complete_graph(n: usize) -> Graph {
        let mut b = GraphBuilder::new(n);
        for u in 0..n {
            for v in (u + 1)..n {
                b.add_edge(u as NodeId, v as NodeId, 1);
            }
        }
        b.build()
    }

    #[test]
    fn components_of_disjoint_paths() {
        let mut b = GraphBuilder::new(6);
        b.add_edge(0, 1, 1);
        b.add_edge(2, 3, 1);
        b.add_edge(4, 5, 1);
        assert_eq!(component_count(&b.build()), 3);
    }

    #[test]
    fn diameter_of_path() {
        let g = path_graph(10);
        let mut rng = Rng::new(1);
        let d = approx_diameter(&g, &mut rng, 8);
        assert!(d >= 5 && d <= 9, "d={d}"); // lower bound ≤ true diameter 9
    }

    #[test]
    fn clustering_of_complete_graph_is_one() {
        let g = complete_graph(8);
        let mut rng = Rng::new(2);
        let c = sample_clustering(&g, &mut rng, 500);
        assert!((c - 1.0).abs() < 1e-9);
    }

    #[test]
    fn clustering_of_path_is_zero() {
        let g = path_graph(20);
        let mut rng = Rng::new(3);
        assert_eq!(sample_clustering(&g, &mut rng, 500), 0.0);
    }

    #[test]
    fn gini_uniform_is_zero() {
        assert!(gini(&[5, 5, 5, 5]).abs() < 1e-9);
    }

    #[test]
    fn gini_concentrated_is_high() {
        let mut values = vec![1usize; 99];
        values.push(1000);
        assert!(gini(&values) > 0.7);
    }

    #[test]
    fn stats_on_small_graph() {
        let g = complete_graph(5);
        let mut rng = Rng::new(4);
        let s = compute_stats(&g, &mut rng);
        assert_eq!(s.n, 5);
        assert_eq!(s.m, 10);
        assert_eq!(s.min_degree, 4);
        assert_eq!(s.max_degree, 4);
        assert_eq!(s.components, 1);
        assert_eq!(s.approx_diameter, 1);
    }
}
