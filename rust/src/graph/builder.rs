//! Edge-list → CSR construction with symmetrization and deduplication.

use super::csr::{Graph, NodeId, Weight};

/// Accumulates undirected edges and produces a validated CSR [`Graph`].
///
/// - parallel edges are merged (weights summed),
/// - self loops are dropped (the partitioning objective ignores them),
/// - the arc lists are sorted by target for reproducibility.
#[derive(Debug, Clone)]
pub struct GraphBuilder {
    n: usize,
    node_weights: Vec<Weight>,
    edges: Vec<(NodeId, NodeId, Weight)>,
}

impl GraphBuilder {
    pub fn new(n: usize) -> Self {
        GraphBuilder {
            n,
            node_weights: vec![1; n],
            edges: Vec::new(),
        }
    }

    /// Pre-size the edge accumulator.
    pub fn with_edge_capacity(n: usize, m: usize) -> Self {
        let mut b = Self::new(n);
        b.edges.reserve(m);
        b
    }

    /// Add an unweighted (weight-1) undirected edge.
    pub fn edge(mut self, u: NodeId, v: NodeId) -> Self {
        self.add_edge(u, v, 1);
        self
    }

    /// Add a weighted undirected edge (in-place form).
    pub fn add_edge(&mut self, u: NodeId, v: NodeId, w: Weight) {
        assert!((u as usize) < self.n && (v as usize) < self.n);
        if u == v {
            return; // drop self loops
        }
        let (a, b) = if u < v { (u, v) } else { (v, u) };
        self.edges.push((a, b, w));
    }

    pub fn set_node_weight(&mut self, v: NodeId, w: Weight) {
        self.node_weights[v as usize] = w;
    }

    pub fn node_weights(mut self, weights: Vec<Weight>) -> Self {
        assert_eq!(weights.len(), self.n);
        self.node_weights = weights;
        self
    }

    /// Current (pre-dedup) edge count; useful for generators.
    pub fn pending_edges(&self) -> usize {
        self.edges.len()
    }

    /// Build the CSR graph.
    pub fn build(mut self) -> Graph {
        // Sort + merge duplicates. Sorting (u,v) pairs also gives sorted
        // adjacency arrays after the counting pass below.
        self.edges.sort_unstable_by_key(|&(u, v, _)| (u, v));
        let mut merged: Vec<(NodeId, NodeId, Weight)> = Vec::with_capacity(self.edges.len());
        for (u, v, w) in self.edges {
            match merged.last_mut() {
                Some(last) if last.0 == u && last.1 == v => last.2 += w,
                _ => merged.push((u, v, w)),
            }
        }

        // Counting pass over both arc directions.
        let n = self.n;
        let mut deg = vec![0usize; n + 1];
        for &(u, v, _) in &merged {
            deg[u as usize + 1] += 1;
            deg[v as usize + 1] += 1;
        }
        for i in 0..n {
            deg[i + 1] += deg[i];
        }
        let xadj = deg.clone();
        let arcs = *xadj.last().unwrap();
        let mut targets = vec![0 as NodeId; arcs];
        let mut weights = vec![0 as Weight; arcs];
        let mut cursor = xadj.clone();
        for &(u, v, w) in &merged {
            let cu = &mut cursor[u as usize];
            targets[*cu] = v;
            weights[*cu] = w;
            *cu += 1;
            let cv = &mut cursor[v as usize];
            targets[*cv] = u;
            weights[*cv] = w;
            *cv += 1;
        }
        // Arc lists per node: merged was sorted by (u,v) so the u→v arcs
        // are already in increasing target order; the v→u arcs are in
        // increasing source order which is also sorted. (Both passes fill
        // monotonically.)
        Graph::from_csr(xadj, targets, weights, self.node_weights)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_merges_weights() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 1, 2);
        b.add_edge(1, 0, 3);
        let g = b.build();
        assert_eq!(g.m(), 1);
        assert_eq!(g.neighbors(0).next(), Some((1, 5)));
        assert!(g.validate().is_ok());
    }

    #[test]
    fn self_loops_dropped() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 0, 5);
        b.add_edge(0, 1, 1);
        let g = b.build();
        assert_eq!(g.m(), 1);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn adjacency_sorted() {
        let mut b = GraphBuilder::new(5);
        for v in [4u32, 2, 3, 1] {
            b.add_edge(0, v, 1);
        }
        let g = b.build();
        assert_eq!(g.adjacent(0), &[1, 2, 3, 4]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn node_weights_respected() {
        let g = GraphBuilder::new(3)
            .node_weights(vec![2, 3, 4])
            .edge(0, 1)
            .build();
        assert_eq!(g.total_node_weight(), 9);
        assert_eq!(g.node_weight(2), 4);
    }

    #[test]
    fn isolated_nodes_allowed() {
        let g = GraphBuilder::new(4).edge(0, 1).build();
        assert_eq!(g.n(), 4);
        assert_eq!(g.degree(2), 0);
        assert_eq!(g.degree(3), 0);
        assert!(g.validate().is_ok());
    }
}
