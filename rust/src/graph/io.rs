//! Graph file I/O.
//!
//! Supported formats:
//! - **METIS** (`.graph`/`.metis`) — the format used by the paper's whole
//!   ecosystem (KaHIP, Metis, the 10th DIMACS challenge instances of
//!   Table 1). 1-indexed adjacency lists, header `n m [fmt [ncon]]` with
//!   fmt ∈ {0,1,10,11} encoding edge/node weights.
//! - **edge list** (`.el`) — `u v [w]` per line, 0-indexed, `#` comments.
//! - **binary** (`.bin`) — fast little-endian CSR dump for large
//!   generated instances (magic `SCLAPG1`).

use super::builder::GraphBuilder;
use super::csr::{Graph, NodeId, Weight};
use std::fs::File;
use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// One parsed METIS adjacency row, in canonical form: 0-indexed
/// neighbors sorted by id, duplicate entries merged (weights summed),
/// self loops dropped — exactly the per-node adjacency the CSR
/// [`GraphBuilder`] produces, so streaming consumers (the
/// `graph::store` METIS→shards converter) and [`read_metis`] agree
/// byte-for-byte on well-formed (symmetric) files.
#[derive(Debug, Default)]
pub struct MetisRow {
    pub node_weight: Weight,
    pub neighbors: Vec<(NodeId, Weight)>,
}

/// Streaming METIS parser: header up front, then one adjacency row per
/// [`MetisReader::next_row`] call into a reused [`MetisRow`] buffer —
/// O(max row) memory, never the whole graph, and one reused line
/// buffer (no per-row allocation on the multi-billion-edge conversion
/// path). Tolerates `%` comment lines anywhere, CRLF line endings and
/// stray whitespace; blank lines inside the adjacency section are
/// isolated nodes (per the format), blank/comment lines after the last
/// node are ignored. Edge weights must be positive (the CSR invariant
/// every consumer — `GraphBuilder` output, shard files — relies on).
pub struct MetisReader<B: BufRead> {
    reader: B,
    /// Reused line buffer.
    line: String,
    /// Node count from the header.
    pub n: usize,
    /// Undirected edge count from the header.
    pub m: usize,
    has_node_w: bool,
    has_edge_w: bool,
    ncon: usize,
    next_node: usize,
}

impl<B: BufRead> MetisReader<B> {
    /// Parse the header; the reader is then positioned on row 0.
    pub fn new(mut reader: B) -> io::Result<Self> {
        let mut line = String::new();
        let head: Vec<usize> = loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                return Err(bad("empty METIS file"));
            }
            let t = line.trim();
            if t.is_empty() || t.starts_with('%') {
                continue;
            }
            break t
                .split_whitespace()
                .map(|tok| tok.parse().map_err(|_| bad("bad header token")))
                .collect::<Result<_, _>>()?;
        };
        if head.len() < 2 {
            return Err(bad("METIS header needs `n m`"));
        }
        let (n, m) = (head[0], head[1]);
        let fmt = head.get(2).copied().unwrap_or(0);
        let has_node_w = fmt / 10 % 10 == 1;
        let has_edge_w = fmt % 10 == 1;
        let ncon = head.get(3).copied().unwrap_or(if has_node_w { 1 } else { 0 });
        Ok(MetisReader {
            reader,
            line,
            n,
            m,
            has_node_w,
            has_edge_w,
            ncon,
            next_node: 0,
        })
    }

    /// Read the adjacency row of the next node into `row` (buffers
    /// reused). Returns `Ok(false)` once all `n` rows are consumed —
    /// at which point the remaining input is validated to contain only
    /// blank/comment lines.
    pub fn next_row(&mut self, row: &mut MetisRow) -> io::Result<bool> {
        if self.next_node >= self.n {
            loop {
                self.line.clear();
                if self.reader.read_line(&mut self.line)? == 0 {
                    return Ok(false);
                }
                let t = self.line.trim();
                if !t.is_empty() && !t.starts_with('%') {
                    return Err(bad("more adjacency lines than nodes"));
                }
            }
        }
        let v = self.next_node;
        // Next non-comment line; a blank line is a (valid) isolated node.
        loop {
            self.line.clear();
            if self.reader.read_line(&mut self.line)? == 0 {
                return Err(bad("fewer adjacency lines than header n"));
            }
            if !self.line.trim_start().starts_with('%') {
                break;
            }
        }
        row.node_weight = 1;
        row.neighbors.clear();
        let mut tokens = self.line.split_whitespace().map(|s| {
            s.parse::<i64>()
                .map_err(|_| bad("non-integer token in adjacency line"))
        });
        if self.has_node_w {
            // Only the first constraint is used as the node weight.
            let mut w = 1;
            for c in 0..self.ncon.max(1) {
                let tok = tokens.next().ok_or_else(|| bad("missing node weight"))??;
                if c == 0 {
                    w = tok;
                }
            }
            if w < 0 {
                // Reject at parse time (like non-positive edge weights)
                // so the in-memory and shard-conversion paths agree
                // instead of the converter failing at its final reopen.
                return Err(bad(&format!("negative node weight {w} (node {})", v + 1)));
            }
            row.node_weight = w as Weight;
        }
        loop {
            let Some(tok) = tokens.next() else { break };
            let u = tok?;
            if u == 0 {
                // The classic off-by-one: 0-indexed input. Without this
                // check `u - 1` underflows into a bogus huge id.
                return Err(bad(&format!(
                    "METIS adjacency is 1-indexed: node {} lists neighbor id 0",
                    v + 1
                )));
            }
            if u < 1 || u as usize > self.n {
                return Err(bad(&format!(
                    "neighbor id {u} out of range 1..={} (node {})",
                    self.n,
                    v + 1
                )));
            }
            let w = if self.has_edge_w {
                tokens.next().ok_or_else(|| bad("missing edge weight"))??
            } else {
                1
            };
            if w <= 0 {
                // CSR invariant: ω > 0. Rejecting here keeps the
                // streaming shard converter and `read_metis` agreeing
                // instead of failing later at shard-read time.
                return Err(bad(&format!(
                    "non-positive edge weight {w} (node {})",
                    v + 1
                )));
            }
            let u = (u - 1) as NodeId;
            if u as usize != v {
                row.neighbors.push((u, w as Weight));
            } // self loop: drop, consistent with GraphBuilder
        }
        // Canonical row: sorted by target, duplicates merged — the form
        // GraphBuilder produces after symmetrization + dedup.
        row.neighbors.sort_unstable_by_key(|&(u, _)| u);
        row.neighbors.dedup_by(|a, b| {
            if a.0 == b.0 {
                b.1 += a.1;
                true
            } else {
                false
            }
        });
        self.next_node += 1;
        Ok(true)
    }

    /// Header-vs-parsed edge-count check shared with the streaming
    /// converter: tolerate sloppy headers (dedup shrinks counts in real
    /// DIMACS files) but reject wildly-off ones.
    pub(crate) fn check_edge_count(&self, parsed_m: usize) -> io::Result<()> {
        if parsed_m != self.m && parsed_m.abs_diff(self.m) > self.m / 2 + 8 {
            return Err(bad(&format!(
                "edge count mismatch: header {}, parsed {parsed_m}",
                self.m
            )));
        }
        Ok(())
    }
}

/// Parse a METIS-format graph from a reader.
pub fn read_metis<R: BufRead>(reader: R) -> io::Result<Graph> {
    let mut metis = MetisReader::new(reader)?;
    let mut builder = GraphBuilder::with_edge_capacity(metis.n, metis.m);
    let mut row = MetisRow::default();
    let mut v: NodeId = 0;
    while metis.next_row(&mut row)? {
        builder.set_node_weight(v, row.node_weight);
        for &(u, w) in &row.neighbors {
            // Each undirected edge appears twice in METIS; keep one copy.
            if v < u {
                builder.add_edge(v, u, w);
            }
        }
        v += 1;
    }
    let g = builder.build();
    metis.check_edge_count(g.m())?;
    Ok(g)
}

/// Write METIS format (fmt=11: node + edge weights, maximal fidelity).
pub fn write_metis<W: Write>(g: &Graph, out: &mut W) -> io::Result<()> {
    writeln!(out, "{} {} 11", g.n(), g.m())?;
    for v in g.nodes() {
        let mut line = String::new();
        line.push_str(&g.node_weight(v).to_string());
        for (u, w) in g.neighbors(v) {
            line.push(' ');
            line.push_str(&(u + 1).to_string());
            line.push(' ');
            line.push_str(&w.to_string());
        }
        writeln!(out, "{line}")?;
    }
    Ok(())
}

/// Parse a 0-indexed edge list: `u v [w]` per line; `#`/`%` comments.
/// Node count is 1 + max id unless `n_hint` is larger.
pub fn read_edge_list<R: BufRead>(reader: R, n_hint: Option<usize>) -> io::Result<Graph> {
    let mut edges: Vec<(NodeId, NodeId, Weight)> = Vec::new();
    let mut max_id: usize = 0;
    for line in reader.lines() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let toks: Vec<&str> = t.split_whitespace().collect();
        if toks.len() < 2 {
            return Err(bad("edge line needs `u v`"));
        }
        let u: usize = toks[0].parse().map_err(|_| bad("bad u"))?;
        let v: usize = toks[1].parse().map_err(|_| bad("bad v"))?;
        let w: Weight = if toks.len() > 2 {
            toks[2].parse().map_err(|_| bad("bad w"))?
        } else {
            1
        };
        max_id = max_id.max(u).max(v);
        edges.push((u as NodeId, v as NodeId, w));
    }
    let n = n_hint.unwrap_or(0).max(if edges.is_empty() { 0 } else { max_id + 1 });
    let mut b = GraphBuilder::with_edge_capacity(n, edges.len());
    for (u, v, w) in edges {
        b.add_edge(u, v, w);
    }
    Ok(b.build())
}

pub fn write_edge_list<W: Write>(g: &Graph, out: &mut W) -> io::Result<()> {
    writeln!(out, "# sclap edge list: n={} m={}", g.n(), g.m())?;
    for (u, v, w) in g.edges() {
        writeln!(out, "{u} {v} {w}")?;
    }
    Ok(())
}

const BIN_MAGIC: &[u8; 8] = b"SCLAPG1\0";

/// Fast binary CSR dump (little endian u64s).
pub fn write_binary<W: Write>(g: &Graph, out: &mut W) -> io::Result<()> {
    out.write_all(BIN_MAGIC)?;
    let n = g.n() as u64;
    let arcs = g.arc_count() as u64;
    out.write_all(&n.to_le_bytes())?;
    out.write_all(&arcs.to_le_bytes())?;
    for v in g.nodes() {
        out.write_all(&(g.node_weight(v) as u64).to_le_bytes())?;
    }
    // xadj implicit via degrees:
    for v in g.nodes() {
        out.write_all(&(g.degree(v) as u64).to_le_bytes())?;
    }
    for v in g.nodes() {
        for (u, w) in g.neighbors(v) {
            out.write_all(&(u as u64).to_le_bytes())?;
            out.write_all(&(w as u64).to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn read_binary<R: Read>(mut reader: R) -> io::Result<Graph> {
    let mut magic = [0u8; 8];
    reader.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        return Err(bad("bad magic"));
    }
    let n_raw = read_u64(&mut reader)?;
    // NodeId is u32: a header beyond that could otherwise smuggle in
    // targets that pass the range check but wrap on the cast below.
    if n_raw > u32::MAX as u64 {
        return Err(bad("node count out of range"));
    }
    let n = n_raw as usize;
    let arcs = read_u64(&mut reader)? as usize;
    // Clamp pre-reservation: a corrupt header must yield an I/O error
    // (EOF below), never an abort from an absurd allocation request.
    let mut node_weights = Vec::with_capacity(n.min(1 << 24));
    for _ in 0..n {
        let w = read_u64(&mut reader)?;
        if w > i64::MAX as u64 {
            return Err(bad("node weight out of range"));
        }
        node_weights.push(w as Weight);
    }
    let mut xadj = Vec::with_capacity((n + 1).min(1 << 24));
    xadj.push(0usize);
    for _ in 0..n {
        let d = read_u64(&mut reader)? as usize;
        let next = xadj
            .last()
            .unwrap()
            .checked_add(d)
            .ok_or_else(|| bad("degree sum overflows"))?;
        xadj.push(next);
    }
    if *xadj.last().unwrap() != arcs {
        return Err(bad("degree sum != arc count"));
    }
    let mut targets = Vec::with_capacity(arcs.min(1 << 26));
    let mut weights = Vec::with_capacity(arcs.min(1 << 26));
    for _ in 0..arcs {
        let t = read_u64(&mut reader)?;
        if t >= n as u64 {
            return Err(bad("arc target out of range"));
        }
        targets.push(t as NodeId);
        let w = read_u64(&mut reader)?;
        // CSR invariant: edge weights are strictly positive i64.
        if w == 0 || w > i64::MAX as u64 {
            return Err(bad("edge weight out of range"));
        }
        weights.push(w as Weight);
    }
    Ok(Graph::from_csr(xadj, targets, weights, node_weights))
}

/// Little-endian u64 read shared with the `graph::store` shard format.
pub(crate) fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Read exactly `len` bytes into `buf` (cleared first), pre-reserving
/// at most `cap` — a header-declared length from a hostile file must
/// surface as an `UnexpectedEof` error, never an allocation abort.
/// Shared with the `graph::store` `SCLAPS2` shard reader.
pub(crate) fn read_bytes_capped<R: Read>(
    r: &mut R,
    len: u64,
    cap: usize,
    buf: &mut Vec<u8>,
) -> io::Result<()> {
    buf.clear();
    buf.reserve(len.min(cap as u64) as usize);
    let got = r.take(len).read_to_end(buf)?;
    if (got as u64) != len {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            "file shorter than its declared payload length",
        ));
    }
    Ok(())
}

/// Load a graph by file extension (.graph/.metis, .el, .bin).
pub fn load_path(path: &Path) -> io::Result<Graph> {
    let ext = path.extension().and_then(|e| e.to_str()).unwrap_or("");
    let file = File::open(path)?;
    match ext {
        "bin" => read_binary(BufReader::new(file)),
        "el" | "edges" | "txt" => read_edge_list(BufReader::new(file), None),
        _ => read_metis(BufReader::new(file)),
    }
}

/// Save a graph by file extension.
pub fn save_path(g: &Graph, path: &Path) -> io::Result<()> {
    let ext = path.extension().and_then(|e| e.to_str()).unwrap_or("");
    let file = File::create(path)?;
    let mut w = BufWriter::new(file);
    match ext {
        "bin" => write_binary(g, &mut w),
        "el" | "edges" | "txt" => write_edge_list(g, &mut w),
        _ => write_metis(g, &mut w),
    }
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use std::io::Cursor;

    fn sample() -> Graph {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 2);
        b.add_edge(1, 2, 1);
        b.add_edge(2, 3, 3);
        b.add_edge(0, 3, 1);
        b.set_node_weight(2, 5);
        b.build()
    }

    #[test]
    fn metis_roundtrip() {
        let g = sample();
        let mut buf = Vec::new();
        write_metis(&g, &mut buf).unwrap();
        let g2 = read_metis(Cursor::new(buf)).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn metis_unweighted_parse() {
        let text = "% comment\n3 2\n2 3\n1\n1\n";
        let g = read_metis(Cursor::new(text)).unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 2);
        assert_eq!(g.node_weight(0), 1);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn metis_edge_weighted_parse() {
        // fmt=1: edge weights; triangle with weights 5,6,7
        let text = "3 3 1\n2 5 3 7\n1 5 3 6\n1 7 2 6\n";
        let g = read_metis(Cursor::new(text)).unwrap();
        assert_eq!(g.m(), 3);
        assert_eq!(g.total_edge_weight(), 18);
    }

    #[test]
    fn metis_rejects_garbage() {
        assert!(read_metis(Cursor::new("not a graph")).is_err());
        assert!(read_metis(Cursor::new("")).is_err());
        assert!(read_metis(Cursor::new("3 1\n2\n1\n")).is_err()); // missing line
        assert!(read_metis(Cursor::new("2 1\n5\n\n")).is_err()); // id range
    }

    #[test]
    fn metis_tolerates_crlf_comments_and_whitespace() {
        // CRLF endings, % comments after the header and between rows,
        // trailing whitespace, and blank/comment lines after the last
        // node must all parse cleanly.
        let text = "% made on windows\r\n3 2\r\n% mid comment\r\n2  \r\n1 3\r\n  2\r\n\r\n% bye\r\n";
        let g = read_metis(Cursor::new(text)).unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 2);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn metis_blank_line_is_isolated_node() {
        let text = "3 1\n2\n1\n\n";
        let g = read_metis(Cursor::new(text)).unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 1);
        assert_eq!(g.degree(2), 0);
    }

    #[test]
    fn metis_rejects_zero_id_with_clear_error() {
        // 0-indexed input must produce a diagnosis, not an underflowed id.
        let err = read_metis(Cursor::new("2 1\n0\n1\n")).unwrap_err();
        assert!(err.to_string().contains("1-indexed"), "{err}");
    }

    #[test]
    fn metis_rejects_non_positive_edge_weights() {
        // fmt=1 with weight 0 / negative: must fail at parse time (the
        // CSR invariant), not later at shard-read time.
        for bad_w in ["0", "-3"] {
            let text = format!("2 1 1\n2 {bad_w}\n1 {bad_w}\n");
            let err = read_metis(Cursor::new(text)).unwrap_err();
            assert!(err.to_string().contains("edge weight"), "{err}");
        }
    }

    #[test]
    fn metis_rejects_negative_node_weights() {
        // fmt=10: a negative vertex weight would poison the balance
        // math in-memory and wrap to 2^64-1 in the shard meta — reject
        // at parse time on both paths.
        let err = read_metis(Cursor::new("2 1 10\n-1 2\n1 1\n")).unwrap_err();
        assert!(err.to_string().contains("node weight"), "{err}");
    }

    #[test]
    fn metis_row_canonical_form() {
        // Duplicate neighbor entries merge (weights summed), self loops
        // drop, rows come out sorted — the GraphBuilder-equivalent form.
        let text = "3 2 1\n2 5 2 3 3 1\n1 5 1 3\n1 1\n";
        let mut r = MetisReader::new(Cursor::new(text)).unwrap();
        let mut row = MetisRow::default();
        assert!(r.next_row(&mut row).unwrap());
        assert_eq!(row.neighbors, vec![(1, 8), (2, 1)]);
        assert!(r.next_row(&mut row).unwrap());
        assert_eq!(row.neighbors, vec![(0, 8)]);
        assert!(r.next_row(&mut row).unwrap());
        assert_eq!(row.neighbors, vec![(0, 1)]);
        assert!(!r.next_row(&mut row).unwrap());
    }

    #[test]
    fn edge_list_roundtrip() {
        let g = sample();
        let mut buf = Vec::new();
        write_edge_list(&g, &mut buf).unwrap();
        let g2 = read_edge_list(Cursor::new(buf), None).unwrap();
        // Node weights are not preserved by edge lists.
        assert_eq!(g.m(), g2.m());
        assert_eq!(g.n(), g2.n());
        assert_eq!(g.total_edge_weight(), g2.total_edge_weight());
    }

    #[test]
    fn edge_list_comments_and_hint() {
        let text = "# c\n0 1\n% also c\n1 2 4\n";
        let g = read_edge_list(Cursor::new(text), Some(10)).unwrap();
        assert_eq!(g.n(), 10);
        assert_eq!(g.m(), 2);
        assert_eq!(g.total_edge_weight(), 5);
    }

    #[test]
    fn binary_roundtrip() {
        let g = sample();
        let mut buf = Vec::new();
        write_binary(&g, &mut buf).unwrap();
        let g2 = read_binary(Cursor::new(buf)).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn binary_rejects_bad_magic() {
        assert!(read_binary(Cursor::new(b"WRONGMAG".to_vec())).is_err());
    }
}
