//! Graph substrate: CSR storage, construction, file I/O, statistics, and
//! embedded test instances.

pub mod builder;
pub mod csr;
pub mod io;
pub mod karate;
pub mod stats;
pub mod subgraph;

pub use builder::GraphBuilder;
pub use csr::{EdgeId, Graph, NodeId, Weight};
pub use karate::karate_club;
pub use stats::{compute_stats, GraphStats};
pub use subgraph::{induced_subgraph, largest_component};
