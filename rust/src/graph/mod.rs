//! Graph substrate: CSR storage, construction, file I/O, statistics,
//! embedded test instances, and the sharded out-of-core storage layer
//! ([`store`]).

pub mod builder;
pub mod csr;
pub mod io;
pub mod karate;
pub mod stats;
pub mod store;
pub mod subgraph;

pub use builder::GraphBuilder;
pub use csr::{EdgeId, Graph, NodeId, Weight};
pub use karate::karate_club;
pub use stats::{compute_stats, GraphStats};
pub use store::{GraphStore, InMemoryStore, ShardedStore};
pub use subgraph::{induced_subgraph, largest_component};
