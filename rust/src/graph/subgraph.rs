//! Subgraph extraction utilities.
//!
//! Real-world benchmark graphs (the paper's Table 1 crawls and social
//! networks) are typically distributed as their giant connected
//! component. Our R-MAT stand-ins produce isolated nodes, so instance
//! generation extracts the largest component to match the structural
//! profile of the originals.

use super::builder::GraphBuilder;
use super::csr::{Graph, NodeId};
use crate::util::union_find::UnionFind;

/// Extract the node-induced subgraph on `nodes` (ids are remapped to
/// `0..nodes.len()` in the given order). Returns the subgraph and the
/// old-id array (`old_of[new] = old`).
pub fn induced_subgraph(g: &Graph, nodes: &[NodeId]) -> (Graph, Vec<NodeId>) {
    let mut new_of = vec![u32::MAX; g.n()];
    for (new, &old) in nodes.iter().enumerate() {
        new_of[old as usize] = new as u32;
    }
    let mut b = GraphBuilder::new(nodes.len());
    for (new, &old) in nodes.iter().enumerate() {
        b.set_node_weight(new as NodeId, g.node_weight(old));
        for (u, w) in g.neighbors(old) {
            let nu = new_of[u as usize];
            if nu != u32::MAX && (new as u32) < nu {
                b.add_edge(new as NodeId, nu, w);
            }
        }
    }
    (b.build(), nodes.to_vec())
}

/// Extract the largest connected component.
pub fn largest_component(g: &Graph) -> Graph {
    if g.n() == 0 {
        return g.clone();
    }
    let mut uf = UnionFind::new(g.n());
    for (u, v, _) in g.edges() {
        uf.union(u as usize, v as usize);
    }
    // count component sizes
    let mut size = vec![0usize; g.n()];
    for v in 0..g.n() {
        size[uf.find(v)] += 1;
    }
    let best_root = (0..g.n()).max_by_key(|&r| size[r]).unwrap();
    let nodes: Vec<NodeId> = (0..g.n())
        .filter(|&v| uf.find(v) == best_root)
        .map(|v| v as NodeId)
        .collect();
    induced_subgraph(g, &nodes).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    #[test]
    fn largest_component_picks_biggest() {
        // triangle + edge + isolated node
        let mut b = GraphBuilder::new(6);
        b.add_edge(0, 1, 1);
        b.add_edge(1, 2, 1);
        b.add_edge(0, 2, 1);
        b.add_edge(3, 4, 1);
        let g = b.build();
        let c = largest_component(&g);
        assert_eq!(c.n(), 3);
        assert_eq!(c.m(), 3);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn induced_subgraph_keeps_weights() {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 7);
        b.add_edge(1, 2, 3);
        b.add_edge(2, 3, 9);
        b.set_node_weight(1, 5);
        let g = b.build();
        let (s, old) = induced_subgraph(&g, &[1, 2]);
        assert_eq!(s.n(), 2);
        assert_eq!(s.m(), 1);
        assert_eq!(s.node_weight(0), 5);
        assert_eq!(s.total_edge_weight(), 3);
        assert_eq!(old, vec![1, 2]);
    }

    #[test]
    fn connected_graph_unchanged_shape() {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 1);
        b.add_edge(1, 2, 1);
        b.add_edge(2, 3, 1);
        let g = b.build();
        let c = largest_component(&g);
        assert_eq!(c.n(), 4);
        assert_eq!(c.m(), 3);
    }

    #[test]
    fn empty_graph_ok() {
        let g = GraphBuilder::new(0).build();
        let c = largest_component(&g);
        assert_eq!(c.n(), 0);
    }
}
