//! Cluster contraction (§3, Fig. 2).
//!
//! Each cluster becomes one coarse node whose weight is the sum of its
//! members' weights; coarse edges aggregate all inter-cluster edge
//! weights. By construction a partition of the coarse graph corresponds
//! to a partition of the finer graph *with the same cut and balance* —
//! the central invariant of the multilevel method (tested below and in
//! `rust/tests/properties.rs`).

use crate::clustering::label_propagation::Clustering;
use crate::graph::csr::{Graph, NodeId, Weight};
use crate::util::fast_reset::FastResetArray;

/// Result of contracting a clustering: the coarse graph plus the
/// fine-node → coarse-node map.
#[derive(Debug, Clone)]
pub struct Contraction {
    pub coarse: Graph,
    /// `map[fine] = coarse` (equals the dense cluster labels).
    pub map: Vec<u32>,
}

/// Contract `clustering` (labels must be dense `0..num_clusters`).
pub fn contract(g: &Graph, clustering: &Clustering) -> Contraction {
    let nc = clustering.num_clusters;
    let labels = &clustering.labels;

    // Bucket fine nodes by coarse id (counting sort) so each coarse
    // node's edges are accumulated in one sweep with a fast-reset map.
    let mut counts = vec![0usize; nc + 1];
    for &l in labels.iter() {
        counts[l as usize + 1] += 1;
    }
    for i in 0..nc {
        counts[i + 1] += counts[i];
    }
    let mut members = vec![0 as NodeId; g.n()];
    {
        let mut cursor = counts.clone();
        for v in g.nodes() {
            let l = labels[v as usize] as usize;
            members[cursor[l]] = v;
            cursor[l] += 1;
        }
    }

    let mut xadj: Vec<usize> = Vec::with_capacity(nc + 1);
    xadj.push(0);
    let mut targets: Vec<NodeId> = Vec::new();
    let mut edge_weights: Vec<Weight> = Vec::new();
    let mut node_weights: Vec<Weight> = vec![0; nc];
    let mut acc: FastResetArray<i64> = FastResetArray::new(nc);

    for c in 0..nc {
        acc.clear();
        for &v in &members[counts[c]..counts[c + 1]] {
            node_weights[c] += g.node_weight(v);
            let adj = g.adjacent(v);
            let ws = g.adjacent_weights(v);
            for (&u, &w) in adj.iter().zip(ws) {
                let cu = labels[u as usize] as usize;
                if cu != c {
                    acc.accumulate(cu, w);
                }
            }
        }
        for &cu in acc.touched() {
            targets.push(cu as NodeId);
            edge_weights.push(acc.value_of_touched(cu));
        }
        xadj.push(targets.len());
    }

    let coarse = Graph::from_csr(xadj, targets, edge_weights, node_weights);
    debug_assert!(coarse.validate().is_ok());
    Contraction {
        coarse,
        map: labels.clone(),
    }
}

/// Project a coarse partition back to the finer graph.
pub fn project_partition(map: &[u32], coarse_blocks: &[u32]) -> Vec<u32> {
    map.iter().map(|&c| coarse_blocks[c as usize]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::label_propagation::Clustering;
    use crate::graph::builder::GraphBuilder;

    /// The Fig. 2 example: a graph whose 3-cluster clustering contracts
    /// to a triangle with aggregated weights.
    #[test]
    fn figure2_example() {
        // 7 nodes, three clusters: {0,1,2}, {3,4}, {5,6}
        let mut b = GraphBuilder::new(7);
        // intra-cluster edges
        b.add_edge(0, 1, 1);
        b.add_edge(1, 2, 1);
        b.add_edge(0, 2, 1);
        b.add_edge(3, 4, 1);
        b.add_edge(5, 6, 1);
        // inter-cluster edges
        b.add_edge(2, 3, 1); // A-B
        b.add_edge(1, 3, 1); // A-B (second edge)
        b.add_edge(4, 5, 1); // B-C
        b.add_edge(0, 6, 1); // A-C
        let g = b.build();
        let clustering = Clustering::from_labels(&g, vec![0, 0, 0, 1, 1, 2, 2]);
        let c = contract(&g, &clustering);
        assert_eq!(c.coarse.n(), 3);
        assert_eq!(c.coarse.m(), 3); // triangle
        // node weights = cluster sizes
        assert_eq!(c.coarse.node_weight(0), 3);
        assert_eq!(c.coarse.node_weight(1), 2);
        assert_eq!(c.coarse.node_weight(2), 2);
        // A-B edge aggregated weight 2
        let ab = c.coarse.neighbors(0).find(|&(u, _)| u == 1).unwrap().1;
        assert_eq!(ab, 2);
        assert!(c.coarse.validate().is_ok());
    }

    #[test]
    fn contraction_preserves_totals() {
        let mut rng = crate::util::rng::Rng::new(1);
        let g = crate::generators::rmat(9, 1500, 0.57, 0.19, 0.19, &mut rng);
        let (clustering, _) = crate::clustering::label_propagation::size_constrained_lpa(
            &g,
            20,
            &Default::default(),
            None,
            None,
            &mut rng,
        );
        let c = contract(&g, &clustering);
        assert_eq!(c.coarse.total_node_weight(), g.total_node_weight());
        // total coarse edge weight = weight of cut edges of the clustering
        assert_eq!(c.coarse.total_edge_weight(), clustering.cut(&g));
    }

    #[test]
    fn projection_preserves_cut() {
        let mut rng = crate::util::rng::Rng::new(2);
        let g = crate::generators::barabasi_albert(400, 3, &mut rng);
        let (clustering, _) = crate::clustering::label_propagation::size_constrained_lpa(
            &g,
            25,
            &Default::default(),
            None,
            None,
            &mut rng,
        );
        let c = contract(&g, &clustering);
        // random 2-partition of the coarse graph
        let coarse_blocks: Vec<u32> =
            (0..c.coarse.n()).map(|_| rng.below(2) as u32).collect();
        let coarse_cut: Weight = c
            .coarse
            .edges()
            .filter(|&(u, v, _)| coarse_blocks[u as usize] != coarse_blocks[v as usize])
            .map(|(_, _, w)| w)
            .sum();
        let fine_blocks = project_partition(&c.map, &coarse_blocks);
        let fine_cut: Weight = g
            .edges()
            .filter(|&(u, v, _)| fine_blocks[u as usize] != fine_blocks[v as usize])
            .map(|(_, _, w)| w)
            .sum();
        assert_eq!(coarse_cut, fine_cut);
        // and block weights match
        for b in 0..2u32 {
            let coarse_w: Weight = c
                .coarse
                .nodes()
                .filter(|&v| coarse_blocks[v as usize] == b)
                .map(|v| c.coarse.node_weight(v))
                .sum();
            let fine_w: Weight = g
                .nodes()
                .filter(|&v| fine_blocks[v as usize] == b)
                .map(|v| g.node_weight(v))
                .sum();
            assert_eq!(coarse_w, fine_w);
        }
    }

    #[test]
    fn contract_to_single_node() {
        let g = GraphBuilder::new(3).edge(0, 1).edge(1, 2).build();
        let clustering = Clustering::from_labels(&g, vec![0, 0, 0]);
        let c = contract(&g, &clustering);
        assert_eq!(c.coarse.n(), 1);
        assert_eq!(c.coarse.m(), 0);
        assert_eq!(c.coarse.node_weight(0), 3);
    }

    #[test]
    fn contract_identity_clustering() {
        let g = GraphBuilder::new(3).edge(0, 1).edge(1, 2).build();
        let clustering = Clustering::from_labels(&g, vec![0, 1, 2]);
        let c = contract(&g, &clustering);
        assert_eq!(c.coarse.n(), 3);
        assert_eq!(c.coarse.m(), 2);
        assert_eq!(&c.coarse, &g);
    }
}
