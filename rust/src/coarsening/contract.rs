//! Cluster contraction (§3, Fig. 2).
//!
//! Each cluster becomes one coarse node whose weight is the sum of its
//! members' weights; coarse edges aggregate all inter-cluster edge
//! weights. By construction a partition of the coarse graph corresponds
//! to a partition of the finer graph *with the same cut and balance* —
//! the central invariant of the multilevel method (tested below and in
//! `rust/tests/properties.rs`).
//!
//! Contraction is embarrassingly parallel across coarse nodes: each
//! coarse node's adjacency depends only on its own members, so
//! [`contract_parallel`] aggregates fixed-size coarse-id chunks on the
//! shared pool and concatenates them in chunk order — **bit-identical**
//! to the sequential [`contract`] for every thread count (the pool's
//! determinism contract; asserted in the tests below).

use crate::clustering::label_propagation::Clustering;
use crate::graph::csr::{Graph, NodeId, Weight};
use crate::partitioning::workspace::VcycleWorkspace;
use crate::util::arena::{scratch, Arena};
use crate::util::fast_reset::FastResetArray;
use crate::util::pool::{ThreadPool, WorkerLocal};

/// Result of contracting a clustering: the coarse graph plus the
/// fine-node → coarse-node map.
#[derive(Debug, Clone)]
pub struct Contraction {
    pub coarse: Graph,
    /// `map[fine] = coarse` (equals the dense cluster labels).
    pub map: Vec<u32>,
}

/// Coarse nodes per parallel-aggregation chunk. Fixed (not derived from
/// the thread count) per the pool determinism contract — though for
/// contraction even a thread-dependent split would be safe, since the
/// merge is by chunk index and each coarse node is independent.
const CONTRACT_CHUNK: usize = 1024;

/// Arc-count threshold below which [`contract_with_pool`] stays
/// sequential (pool dispatch overhead dominates on tiny levels).
const CONTRACT_PARALLEL_MIN_ARCS: usize = 1 << 15;

/// Bucket fine nodes by coarse id (counting sort) so each coarse node's
/// edges are accumulated in one sweep, filling caller-supplied (leased
/// or owned) buffers: `counts` becomes the prefix counts, `members` the
/// bucketed node list; `cursor` is pure scratch.
fn bucket_members_into(
    g: &Graph,
    labels: &[u32],
    nc: usize,
    counts: &mut Vec<usize>,
    members: &mut Vec<NodeId>,
    cursor: &mut Vec<usize>,
) {
    counts.clear();
    counts.resize(nc + 1, 0);
    for &l in labels.iter() {
        counts[l as usize + 1] += 1;
    }
    for i in 0..nc {
        counts[i + 1] += counts[i];
    }
    members.clear();
    members.resize(g.n(), 0 as NodeId);
    cursor.clear();
    cursor.extend_from_slice(counts.as_slice());
    for v in g.nodes() {
        let l = labels[v as usize] as usize;
        members[cursor[l]] = v;
        cursor[l] += 1;
    }
}

/// Aggregate the coarse CSR rows for coarse ids `lo..hi`. The inner loop
/// is shared verbatim between the sequential and parallel paths so their
/// outputs are identical by construction.
#[allow(clippy::too_many_arguments)]
fn aggregate_range(
    g: &Graph,
    labels: &[u32],
    counts: &[usize],
    members: &[NodeId],
    lo: usize,
    hi: usize,
    acc: &mut FastResetArray<i64>,
    xadj: &mut Vec<usize>,
    targets: &mut Vec<NodeId>,
    edge_weights: &mut Vec<Weight>,
    node_weights: &mut Vec<Weight>,
) {
    for c in lo..hi {
        acc.clear();
        let mut nw: Weight = 0;
        for &v in &members[counts[c]..counts[c + 1]] {
            nw += g.node_weight(v);
            let adj = g.adjacent(v);
            let ws = g.adjacent_weights(v);
            for (&u, &w) in adj.iter().zip(ws) {
                let cu = labels[u as usize] as usize;
                if cu != c {
                    acc.accumulate(cu, w);
                }
            }
        }
        node_weights.push(nw);
        for &cu in acc.touched() {
            targets.push(cu as NodeId);
            edge_weights.push(acc.value_of_touched(cu));
        }
        xadj.push(targets.len());
    }
}

/// Contract `clustering` (labels must be dense `0..num_clusters`).
pub fn contract(g: &Graph, clustering: &Clustering) -> Contraction {
    contract_leased(g, clustering, None)
}

/// [`contract`] with bucket/accumulator scratch leased from `arena`
/// when one is supplied — the workspace path the multilevel driver
/// takes so steady-state levels reuse capacity instead of allocating.
/// The CSR output buffers stay owned (they escape into the coarse
/// [`Graph`]).
pub fn contract_leased(g: &Graph, clustering: &Clustering, arena: Option<&Arena>) -> Contraction {
    // A contraction pass is one of the long units between cancellation
    // checkpoints: poll once on entry (no-op when no token is ambient).
    crate::util::cancel::checkpoint();
    let nc = clustering.num_clusters;
    let labels = &clustering.labels;

    let mut counts_l = arena.map(|a| a.lease::<Vec<usize>>(nc + 1));
    let mut counts_o = Vec::new();
    let counts = scratch(&mut counts_l, &mut counts_o);
    let mut members_l = arena.map(|a| a.lease::<Vec<NodeId>>(g.n()));
    let mut members_o = Vec::new();
    let members = scratch(&mut members_l, &mut members_o);
    let mut cursor_l = arena.map(|a| a.lease::<Vec<usize>>(nc + 1));
    let mut cursor_o = Vec::new();
    let cursor = scratch(&mut cursor_l, &mut cursor_o);
    bucket_members_into(g, labels, nc, counts, members, cursor);

    let mut xadj: Vec<usize> = Vec::with_capacity(nc + 1);
    xadj.push(0);
    let mut targets: Vec<NodeId> = Vec::new();
    let mut edge_weights: Vec<Weight> = Vec::new();
    let mut node_weights: Vec<Weight> = Vec::with_capacity(nc);
    let mut acc_l = arena.map(|a| a.lease::<FastResetArray<i64>>(nc.max(1)));
    let mut acc_o = FastResetArray::new(0);
    let acc = scratch(&mut acc_l, &mut acc_o);
    acc.ensure_capacity(nc);

    aggregate_range(
        g,
        labels,
        counts,
        members,
        0,
        nc,
        acc,
        &mut xadj,
        &mut targets,
        &mut edge_weights,
        &mut node_weights,
    );

    let coarse = Graph::from_csr(xadj, targets, edge_weights, node_weights);
    debug_assert!(coarse.validate().is_ok());
    Contraction {
        coarse,
        map: labels.clone(),
    }
}

/// Per-chunk partial coarse CSR (xadj is chunk-local, rebased on merge).
struct ChunkCsr {
    xadj: Vec<usize>,
    targets: Vec<NodeId>,
    edge_weights: Vec<Weight>,
    node_weights: Vec<Weight>,
}

/// Pool-parallel contraction: aggregate fixed coarse-id chunks on the
/// pool workers and concatenate in chunk order. Output is bit-identical
/// to [`contract`] for every pool size.
pub fn contract_parallel(g: &Graph, clustering: &Clustering, pool: &ThreadPool) -> Contraction {
    contract_parallel_ws(g, clustering, pool, None)
}

/// [`contract_parallel`] with scratch leased from a workspace when one
/// is supplied: bucket buffers from the caller shard, per-chunk
/// accumulators from each worker's own shard (uncontended in the steady
/// state). Falls back to per-call [`WorkerLocal`] scratch otherwise.
pub fn contract_parallel_ws(
    g: &Graph,
    clustering: &Clustering,
    pool: &ThreadPool,
    ws: Option<&VcycleWorkspace>,
) -> Contraction {
    crate::util::cancel::checkpoint();
    let nc = clustering.num_clusters;
    let labels = &clustering.labels;

    let caller = ws.map(|w| w.caller());
    let mut counts_l = caller.map(|a| a.lease::<Vec<usize>>(nc + 1));
    let mut counts_o = Vec::new();
    let mut members_l = caller.map(|a| a.lease::<Vec<NodeId>>(g.n()));
    let mut members_o = Vec::new();
    {
        let counts = scratch(&mut counts_l, &mut counts_o);
        let members = scratch(&mut members_l, &mut members_o);
        let mut cursor_l = caller.map(|a| a.lease::<Vec<usize>>(nc + 1));
        let mut cursor_o = Vec::new();
        let cursor = scratch(&mut cursor_l, &mut cursor_o);
        bucket_members_into(g, labels, nc, counts, members, cursor);
    }
    // Re-borrow shared for the pool closure below.
    let counts: &[usize] = match counts_l.as_ref() {
        Some(l) => l.as_slice(),
        None => counts_o.as_slice(),
    };
    let members: &[NodeId] = match members_l.as_ref() {
        Some(l) => l.as_slice(),
        None => members_o.as_slice(),
    };

    let num_chunks = nc.div_ceil(CONTRACT_CHUNK).max(1);
    let worker_scratch = match ws {
        Some(_) => None,
        None => Some(WorkerLocal::new(pool.threads(), || {
            FastResetArray::new(nc.max(1))
        })),
    };
    let chunks: Vec<ChunkCsr> = pool.map_indexed(num_chunks, |worker, chunk| {
        let lo = chunk * CONTRACT_CHUNK;
        let hi = (lo + CONTRACT_CHUNK).min(nc);
        let mut acc_l = ws.map(|w| w.worker(worker).lease::<FastResetArray<i64>>(nc.max(1)));
        let acc = match acc_l.as_mut() {
            Some(l) => &mut **l,
            // SAFETY: `worker` is the pool-provided id (WorkerLocal
            // contract); this arm only runs when `ws` is None, so
            // `worker_scratch` is Some.
            None => unsafe { worker_scratch.as_ref().unwrap().get_mut(worker) },
        };
        let mut xadj = Vec::with_capacity(hi - lo + 1);
        xadj.push(0);
        let mut out = ChunkCsr {
            xadj,
            targets: Vec::new(),
            edge_weights: Vec::new(),
            node_weights: Vec::with_capacity(hi - lo),
        };
        aggregate_range(
            g,
            labels,
            counts,
            members,
            lo,
            hi,
            acc,
            &mut out.xadj,
            &mut out.targets,
            &mut out.edge_weights,
            &mut out.node_weights,
        );
        out
    });

    // Deterministic merge: concatenate in chunk order, rebasing offsets.
    let total_arcs: usize = chunks.iter().map(|c| c.targets.len()).sum();
    let mut xadj: Vec<usize> = Vec::with_capacity(nc + 1);
    xadj.push(0);
    let mut targets: Vec<NodeId> = Vec::with_capacity(total_arcs);
    let mut edge_weights: Vec<Weight> = Vec::with_capacity(total_arcs);
    let mut node_weights: Vec<Weight> = Vec::with_capacity(nc);
    for chunk in chunks {
        let base = targets.len();
        for &off in &chunk.xadj[1..] {
            xadj.push(base + off);
        }
        targets.extend_from_slice(&chunk.targets);
        edge_weights.extend_from_slice(&chunk.edge_weights);
        node_weights.extend_from_slice(&chunk.node_weights);
    }

    let coarse = Graph::from_csr(xadj, targets, edge_weights, node_weights);
    debug_assert!(coarse.validate().is_ok());
    Contraction {
        coarse,
        map: labels.clone(),
    }
}

/// Contraction entry point for the multilevel driver: parallel when a
/// pool with >1 thread is supplied and the level is big enough for the
/// dispatch overhead to pay off, sequential otherwise. Both paths
/// produce identical output, so the choice never affects results.
pub fn contract_with_pool(
    g: &Graph,
    clustering: &Clustering,
    pool: Option<&ThreadPool>,
) -> Contraction {
    match pool {
        Some(pool) if pool.threads() > 1 && g.arc_count() >= CONTRACT_PARALLEL_MIN_ARCS => {
            contract_parallel(g, clustering, pool)
        }
        _ => contract(g, clustering),
    }
}

/// [`contract_with_pool`] through a shared [`ExecutionCtx`] — the
/// multilevel driver's entry point after the ExecutionCtx refactor.
/// With a context, both the parallel and the sequential path lease
/// their scratch from the context's workspace, so repeated levels
/// reuse capacity.
pub fn contract_with_ctx(
    g: &Graph,
    clustering: &Clustering,
    ctx: Option<&crate::util::exec::ExecutionCtx>,
) -> Contraction {
    match ctx {
        Some(c) if c.threads() > 1 && g.arc_count() >= CONTRACT_PARALLEL_MIN_ARCS => {
            contract_parallel_ws(g, clustering, c.pool(), Some(c.workspace()))
        }
        Some(c) => contract_leased(g, clustering, Some(c.workspace().caller())),
        None => contract(g, clustering),
    }
}

/// Streaming contraction over a [`GraphStore`]: one pass over the
/// shards (each arc read once, at most one shard resident), building
/// the coarse graph — which fits in RAM by the premise of out-of-core
/// coarsening — incrementally.
///
/// **Exactly** reproduces [`contract`]'s output: `contract` visits each
/// coarse node's members in increasing fine id (the bucket fill order)
/// and pushes coarse arcs in first-touch order; streaming fine nodes in
/// natural order visits every cluster's members in that same relative
/// order, so maintaining per-coarse-row first-touch arc lists yields
/// the identical CSR. `rust/tests/sharded_store.rs` asserts equality
/// against the in-memory path for shard counts {1, 2, 7}.
pub fn contract_store(
    store: &dyn crate::graph::store::GraphStore,
    clustering: &Clustering,
) -> std::io::Result<Contraction> {
    contract_store_with_ctx(store, clustering, None)
}

/// [`contract_store`] with aggregation scratch leased from the
/// context's workspace when one is supplied (the out-of-core driver's
/// path — every external level reuses the same flat buffers).
pub fn contract_store_with_ctx(
    store: &dyn crate::graph::store::GraphStore,
    clustering: &Clustering,
    ctx: Option<&crate::util::exec::ExecutionCtx>,
) -> std::io::Result<Contraction> {
    use std::collections::hash_map::Entry;
    use std::collections::HashMap;

    let nc = clustering.num_clusters;
    let labels = &clustering.labels;
    assert_eq!(labels.len(), store.n());
    let arena = ctx.map(|c| c.workspace().caller());

    // Aggregated coarse arcs as one flat `(row, target, weight)` run in
    // global first-touch order — a single growing buffer instead of one
    // `Vec` per coarse node. `slot` locates the accumulator of an
    // existing (row, target) pair; it is never iterated — output order
    // comes from the flat run alone, so the HashMap cannot leak
    // nondeterminism. `row_len[c + 1]` counts row `c`'s arcs for the
    // counting sort below.
    let mut arcs_l = arena.map(|a| a.lease::<Vec<(u32, NodeId, Weight)>>(nc));
    let mut arcs_o = Vec::new();
    let arcs = scratch(&mut arcs_l, &mut arcs_o);
    let mut slot_l = arena.map(|a| a.lease::<HashMap<(u32, u32), usize>>(nc));
    let mut slot_o = HashMap::new();
    let slot = scratch(&mut slot_l, &mut slot_o);
    let mut row_len_l = arena.map(|a| a.lease::<Vec<usize>>(nc + 1));
    let mut row_len_o = Vec::new();
    let row_len = scratch(&mut row_len_l, &mut row_len_o);
    row_len.resize(nc + 1, 0);

    let mut cursor = store.cursor();
    for s in 0..store.num_shards() {
        // Streaming contraction checkpoints per shard — the natural
        // chunk boundary of the semi-external pass.
        crate::util::cancel::checkpoint();
        let view = cursor.load(s)?;
        let (lo, hi) = view.span();
        for v in lo..hi {
            let c = labels[v];
            let (adj, ws) = view.adjacent(v as NodeId);
            for (&u, &w) in adj.iter().zip(ws) {
                let cu = labels[u as usize];
                if cu == c {
                    continue;
                }
                match slot.entry((c, cu)) {
                    Entry::Occupied(e) => arcs[*e.get()].2 += w,
                    Entry::Vacant(e) => {
                        e.insert(arcs.len());
                        arcs.push((c, cu as NodeId, w));
                        row_len[c as usize + 1] += 1;
                    }
                }
            }
        }
    }

    // Emit the CSR with a stable counting sort by row: prefix-sum the
    // per-row counts into start offsets, then place arcs in their
    // global first-touch order. Stability preserves each row's
    // first-touch order exactly, so the output is bit-identical to the
    // old per-row representation (and hence to `contract` — see the
    // doc contract above). `row_len` doubles as the placement cursor;
    // `xadj` is cloned from the pristine offsets because it escapes
    // into the coarse graph.
    for c in 0..nc {
        row_len[c + 1] += row_len[c];
    }
    let xadj: Vec<usize> = row_len.clone();
    let total_arcs = arcs.len();
    debug_assert_eq!(xadj[nc], total_arcs);
    let mut targets: Vec<NodeId> = vec![0; total_arcs];
    let mut edge_weights: Vec<Weight> = vec![0; total_arcs];
    for &(row, target, weight) in arcs.iter() {
        let pos = row_len[row as usize];
        targets[pos] = target;
        edge_weights[pos] = weight;
        row_len[row as usize] += 1;
    }
    // Coarse node weights are the cluster weights (what `contract`
    // computes by summing members).
    let coarse = Graph::from_csr(xadj, targets, edge_weights, clustering.cluster_weights.clone());
    debug_assert!(coarse.validate().is_ok());
    Ok(Contraction {
        coarse,
        map: labels.clone(),
    })
}

/// Project a coarse partition back to the finer graph.
pub fn project_partition(map: &[u32], coarse_blocks: &[u32]) -> Vec<u32> {
    map.iter().map(|&c| coarse_blocks[c as usize]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::label_propagation::Clustering;
    use crate::graph::builder::GraphBuilder;

    /// The Fig. 2 example: a graph whose 3-cluster clustering contracts
    /// to a triangle with aggregated weights.
    #[test]
    fn figure2_example() {
        // 7 nodes, three clusters: {0,1,2}, {3,4}, {5,6}
        let mut b = GraphBuilder::new(7);
        // intra-cluster edges
        b.add_edge(0, 1, 1);
        b.add_edge(1, 2, 1);
        b.add_edge(0, 2, 1);
        b.add_edge(3, 4, 1);
        b.add_edge(5, 6, 1);
        // inter-cluster edges
        b.add_edge(2, 3, 1); // A-B
        b.add_edge(1, 3, 1); // A-B (second edge)
        b.add_edge(4, 5, 1); // B-C
        b.add_edge(0, 6, 1); // A-C
        let g = b.build();
        let clustering = Clustering::from_labels(&g, vec![0, 0, 0, 1, 1, 2, 2]);
        let c = contract(&g, &clustering);
        assert_eq!(c.coarse.n(), 3);
        assert_eq!(c.coarse.m(), 3); // triangle
        // node weights = cluster sizes
        assert_eq!(c.coarse.node_weight(0), 3);
        assert_eq!(c.coarse.node_weight(1), 2);
        assert_eq!(c.coarse.node_weight(2), 2);
        // A-B edge aggregated weight 2
        let ab = c.coarse.neighbors(0).find(|&(u, _)| u == 1).unwrap().1;
        assert_eq!(ab, 2);
        assert!(c.coarse.validate().is_ok());
    }

    #[test]
    fn contraction_preserves_totals() {
        let mut rng = crate::util::rng::Rng::new(1);
        let g = crate::generators::rmat(9, 1500, 0.57, 0.19, 0.19, &mut rng);
        let (clustering, _) = crate::clustering::label_propagation::size_constrained_lpa(
            &g,
            20,
            &Default::default(),
            None,
            None,
            &mut rng,
        );
        let c = contract(&g, &clustering);
        assert_eq!(c.coarse.total_node_weight(), g.total_node_weight());
        // total coarse edge weight = weight of cut edges of the clustering
        assert_eq!(c.coarse.total_edge_weight(), clustering.cut(&g));
    }

    #[test]
    fn projection_preserves_cut() {
        let mut rng = crate::util::rng::Rng::new(2);
        let g = crate::generators::barabasi_albert(400, 3, &mut rng);
        let (clustering, _) = crate::clustering::label_propagation::size_constrained_lpa(
            &g,
            25,
            &Default::default(),
            None,
            None,
            &mut rng,
        );
        let c = contract(&g, &clustering);
        // random 2-partition of the coarse graph
        let coarse_blocks: Vec<u32> =
            (0..c.coarse.n()).map(|_| rng.below(2) as u32).collect();
        let coarse_cut: Weight = c
            .coarse
            .edges()
            .filter(|&(u, v, _)| coarse_blocks[u as usize] != coarse_blocks[v as usize])
            .map(|(_, _, w)| w)
            .sum();
        let fine_blocks = project_partition(&c.map, &coarse_blocks);
        let fine_cut: Weight = g
            .edges()
            .filter(|&(u, v, _)| fine_blocks[u as usize] != fine_blocks[v as usize])
            .map(|(_, _, w)| w)
            .sum();
        assert_eq!(coarse_cut, fine_cut);
        // and block weights match
        for b in 0..2u32 {
            let coarse_w: Weight = c
                .coarse
                .nodes()
                .filter(|&v| coarse_blocks[v as usize] == b)
                .map(|v| c.coarse.node_weight(v))
                .sum();
            let fine_w: Weight = g
                .nodes()
                .filter(|&v| fine_blocks[v as usize] == b)
                .map(|v| g.node_weight(v))
                .sum();
            assert_eq!(coarse_w, fine_w);
        }
    }

    #[test]
    fn contract_to_single_node() {
        let g = GraphBuilder::new(3).edge(0, 1).edge(1, 2).build();
        let clustering = Clustering::from_labels(&g, vec![0, 0, 0]);
        let c = contract(&g, &clustering);
        assert_eq!(c.coarse.n(), 1);
        assert_eq!(c.coarse.m(), 0);
        assert_eq!(c.coarse.node_weight(0), 3);
    }

    #[test]
    fn contract_identity_clustering() {
        let g = GraphBuilder::new(3).edge(0, 1).edge(1, 2).build();
        let clustering = Clustering::from_labels(&g, vec![0, 1, 2]);
        let c = contract(&g, &clustering);
        assert_eq!(c.coarse.n(), 3);
        assert_eq!(c.coarse.m(), 2);
        assert_eq!(&c.coarse, &g);
    }

    #[test]
    fn parallel_contract_matches_sequential() {
        // Identity clustering keeps nc large (> CONTRACT_CHUNK) so the
        // parallel path really splits into several chunks.
        let mut rng = crate::util::rng::Rng::new(5);
        let g = crate::generators::rmat(12, 20000, 0.57, 0.19, 0.19, &mut rng);
        for clustering in [
            Clustering::from_labels(&g, (0..g.n() as u32).collect()),
            crate::clustering::label_propagation::size_constrained_lpa(
                &g,
                30,
                &Default::default(),
                None,
                None,
                &mut rng,
            )
            .0,
        ] {
            let seq = contract(&g, &clustering);
            for threads in [1usize, 2, 4] {
                let pool = ThreadPool::new(threads);
                let par = contract_parallel(&g, &clustering, &pool);
                assert_eq!(seq.coarse, par.coarse, "threads={threads}");
                assert_eq!(seq.map, par.map);
            }
        }
    }

    #[test]
    fn contract_store_matches_in_memory_for_any_shard_count() {
        use crate::graph::store::InMemoryStore;
        let mut rng = crate::util::rng::Rng::new(21);
        let g = crate::generators::barabasi_albert(1200, 3, &mut rng);
        let (clustering, _) = crate::clustering::label_propagation::size_constrained_lpa(
            &g,
            25,
            &Default::default(),
            None,
            None,
            &mut rng,
        );
        let reference = contract(&g, &clustering);
        for shards in [1usize, 2, 5, 9] {
            let store = InMemoryStore::with_shards(&g, shards);
            let streamed = contract_store(&store, &clustering).unwrap();
            assert_eq!(reference.coarse, streamed.coarse, "shards={shards}");
            assert_eq!(reference.map, streamed.map);
        }
    }

    #[test]
    fn contract_with_pool_gates_small_levels() {
        // Tiny graph: must take the sequential path and still be correct.
        let g = GraphBuilder::new(4).edge(0, 1).edge(2, 3).build();
        let pool = ThreadPool::new(4);
        let clustering = Clustering::from_labels(&g, vec![0, 0, 1, 1]);
        let c = contract_with_pool(&g, &clustering, Some(&pool));
        assert_eq!(c.coarse.n(), 2);
        assert_eq!(c.coarse, contract(&g, &clustering).coarse);
    }
}
