//! Multilevel hierarchy construction (the coarsening phase).
//!
//! Repeatedly cluster + contract until the graph is small enough for
//! initial partitioning: the paper's threshold is
//! `n_coarse ≤ max(60·k, n/(60·k))` (§3.1). Supports both the paper's
//! cluster-contraction scheme and the matching baseline, and threads an
//! optional input partition through the levels for V-cycles (§B.1).

use crate::clustering::async_lpa::parallel_async_sclap;
use crate::clustering::ensemble::ensemble_sclap;
use crate::clustering::label_propagation::{size_constrained_lpa_ws, Clustering, LpaConfig};
use crate::coarsening::contract::{contract_with_ctx, Contraction};
use crate::coarsening::matching::heavy_edge_matching;
use crate::graph::csr::{Graph, Weight};
use crate::obs::trace;
use crate::util::exec::ExecutionCtx;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Which coarsening algorithm builds each level.
#[derive(Debug, Clone)]
pub enum CoarseningScheme {
    /// The paper's contribution: contract size-constrained LPA clusters.
    ClusterLpa {
        lpa: LpaConfig,
        /// cluster-size factor f (paper default 18): W = L_max / (f·k)
        size_factor: f64,
        /// number of ensemble clusterings (None = single run)
        ensemble: Option<usize>,
    },
    /// Baseline: heavy-edge matching (KaFFPa/Metis style).
    Matching { two_hop: bool },
}

/// One coarse level: the contracted graph plus the map from the next
/// finer graph's nodes to this graph's nodes.
#[derive(Debug, Clone)]
pub struct Level {
    pub graph: Graph,
    pub map: Vec<u32>,
}

/// The full coarsening output.
#[derive(Debug)]
pub struct Hierarchy {
    /// Levels from finest-coarse (index 0) to coarsest (last). Empty if
    /// the input was already small enough.
    pub levels: Vec<Level>,
    /// Input partition projected onto the coarsest graph (V-cycles).
    pub coarsest_partition: Option<Vec<u32>>,
}

impl Hierarchy {
    pub fn coarsest<'a>(&'a self, input: &'a Graph) -> &'a Graph {
        self.levels.last().map(|l| &l.graph).unwrap_or(input)
    }

    /// Number of contraction steps performed.
    pub fn depth(&self) -> usize {
        self.levels.len()
    }
}

/// Paper §2.1: `L_max := (1+ε)·c(V)/k + max_v c(v)`.
pub fn l_max(total_weight: Weight, k: usize, epsilon: f64, max_node_weight: Weight) -> Weight {
    ((1.0 + epsilon) * total_weight as f64 / k as f64).ceil() as Weight + max_node_weight
}

/// Paper §3.1 stopping size: `max(60k, n/(60k))`.
pub fn coarsest_size_threshold(n_input: usize, k: usize) -> usize {
    (60 * k).max(n_input / (60 * k).max(1))
}

/// Compute the clustering for one coarsening step.
fn cluster_once(
    g: &Graph,
    params: &CoarseningParams,
    respect: Option<&[u32]>,
    rng: &mut Rng,
) -> Clustering {
    let (k, epsilon) = (params.k, params.epsilon);
    match &params.scheme {
        CoarseningScheme::ClusterLpa {
            lpa,
            size_factor,
            ensemble,
        } => {
            let lmax = l_max(g.total_node_weight(), k, epsilon, g.max_node_weight());
            // U := max(max_v c(v), W) with W = L_max / (f·k)
            let w = (lmax as f64 / (size_factor * k as f64)).floor() as Weight;
            let upper = w.max(g.max_node_weight()).max(1);
            match ensemble {
                Some(count) => ensemble_sclap(g, upper, lpa, *count, respect, rng),
                // The coloring-based parallel asynchronous engine —
                // selected by configuration only (never by thread count
                // or graph size), so results stay thread-invariant. A
                // missing ctx falls back to an inline sequential one:
                // identical output, by the pool contract.
                None if params.parallel_lpa => {
                    let fallback;
                    let ctx: &ExecutionCtx = match params.ctx.as_deref() {
                        Some(c) => c,
                        None => {
                            fallback = ExecutionCtx::sequential();
                            &fallback
                        }
                    };
                    parallel_async_sclap(g, upper, lpa, respect, ctx, rng).0
                }
                None => {
                    let ws = params.ctx.as_deref().map(|c| c.workspace());
                    size_constrained_lpa_ws(g, upper, lpa, None, respect, ws, rng).0
                }
            }
        }
        CoarseningScheme::Matching { two_hop } => {
            let lmax = l_max(g.total_node_weight(), k, epsilon, g.max_node_weight());
            // Metis-style bound: pair weight well under a block's weight.
            let upper = (lmax as f64 / 1.5).max(2.0) as Weight;
            let mut c = heavy_edge_matching(g, upper, *two_hop, rng);
            if let Some(blocks) = respect {
                // Baseline V-cycles: split any matched pair crossing a
                // block boundary (cut edges must not be contracted).
                c = split_cross_block_pairs(g, c, blocks);
            }
            c
        }
    }
}

fn split_cross_block_pairs(g: &Graph, c: Clustering, blocks: &[u32]) -> Clustering {
    let mut labels = c.labels;
    let n = labels.len();
    // Any cluster containing two blocks is split: each member keeps a
    // label derived from (cluster, block) pairs.
    let mut seen: std::collections::HashMap<(u32, u32), u32> = std::collections::HashMap::new();
    let mut next = 0u32;
    for v in 0..n {
        let key = (labels[v], blocks[v]);
        let id = *seen.entry(key).or_insert_with(|| {
            let id = next;
            next += 1;
            id
        });
        labels[v] = id;
    }
    Clustering::from_labels(g, labels)
}

/// Build the full hierarchy.
///
/// `respect`: input partition for V-cycles — every cluster stays inside
/// one block, and the returned `coarsest_partition` is the projection.
/// `min_shrink`: abort coarsening when a step shrinks the node count by
/// less than this factor (guards against stalls; matching on complex
/// networks routinely stalls, which is the paper's motivation).
pub struct CoarseningParams {
    pub k: usize,
    pub epsilon: f64,
    pub scheme: CoarseningScheme,
    pub max_levels: usize,
    pub min_shrink: f64,
    /// Shared execution context for the parallel phases of coarsening
    /// (cluster contraction, and the parallel asynchronous LPA when
    /// [`parallel_lpa`](CoarseningParams::parallel_lpa) is set). `None`
    /// (or a 1-thread context) runs sequentially; results are
    /// bit-identical either way — the context only changes wall-clock,
    /// never output (util::pool contract).
    pub ctx: Option<Arc<ExecutionCtx>>,
    /// Use the coloring-based parallel *asynchronous* SCLaP
    /// (`clustering::async_lpa`) for the non-ensemble cluster steps
    /// instead of the sequential engine. A different (equally
    /// deterministic) algorithm — an opt-in configuration choice, so
    /// output never depends on the thread count.
    pub parallel_lpa: bool,
}

impl CoarseningParams {
    pub fn new(k: usize, epsilon: f64, scheme: CoarseningScheme) -> Self {
        CoarseningParams {
            k,
            epsilon,
            scheme,
            max_levels: 64,
            min_shrink: 0.98,
            ctx: None,
            parallel_lpa: false,
        }
    }
}

pub fn coarsen(
    input: &Graph,
    params: &CoarseningParams,
    respect: Option<&[u32]>,
    rng: &mut Rng,
) -> Hierarchy {
    let threshold = coarsest_size_threshold(input.n(), params.k);
    let mut levels: Vec<Level> = Vec::new();
    let mut partition: Option<Vec<u32>> = respect.map(|r| r.to_vec());

    loop {
        let current: &Graph = levels.last().map(|l| &l.graph).unwrap_or(input);
        if current.n() <= threshold || levels.len() >= params.max_levels {
            break;
        }
        let level_span = trace::span(
            "coarsen_level",
            &[("level", levels.len() as i64), ("n", current.n() as i64)],
        );
        let clustering = cluster_once(current, params, partition.as_deref(), rng);
        if clustering.num_clusters as f64 > params.min_shrink * current.n() as f64 {
            break; // stalled (span guard closes the open level)
        }
        let Contraction { coarse, map } =
            contract_with_ctx(current, &clustering, params.ctx.as_deref());
        drop(level_span);
        trace::counter(
            "contraction",
            &[
                ("level", levels.len() as i64),
                ("clusters", clustering.num_clusters as i64),
                ("coarse_n", coarse.n() as i64),
                ("coarse_m", coarse.m() as i64),
            ],
        );
        // Project the partition: every cluster is inside one block.
        partition = partition.map(|p| {
            let mut coarse_part = vec![u32::MAX; coarse.n()];
            for (v, &c) in map.iter().enumerate() {
                debug_assert!(
                    coarse_part[c as usize] == u32::MAX || coarse_part[c as usize] == p[v],
                    "cluster crosses blocks"
                );
                coarse_part[c as usize] = p[v];
            }
            coarse_part
        });
        levels.push(Level { graph: coarse, map });
    }

    Hierarchy {
        levels,
        coarsest_partition: partition,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::label_propagation::NodeOrdering;
    use crate::generators;

    fn cluster_scheme() -> CoarseningScheme {
        CoarseningScheme::ClusterLpa {
            lpa: LpaConfig::clustering(10, NodeOrdering::Degree),
            size_factor: 18.0,
            ensemble: None,
        }
    }

    #[test]
    fn lmax_formula() {
        // unweighted: (1+0.03)*1000/4 + 1 = 258.5 -> ceil 258 + 1
        assert_eq!(l_max(1000, 4, 0.03, 1), 259);
        assert_eq!(l_max(100, 2, 0.0, 1), 51);
    }

    #[test]
    fn threshold_formula() {
        assert_eq!(coarsest_size_threshold(1_000_000, 2), 8333);
        assert_eq!(coarsest_size_threshold(1000, 16), 960);
        assert_eq!(coarsest_size_threshold(10, 4), 240);
    }

    #[test]
    fn cluster_coarsening_shrinks_complex_network() {
        let mut rng = Rng::new(1);
        let g = crate::graph::subgraph::largest_component(&generators::rmat(
            12, 16000, 0.57, 0.19, 0.19, &mut rng,
        ));
        let params = CoarseningParams::new(4, 0.03, cluster_scheme());
        let h = coarsen(&g, &params, None, &mut Rng::new(2));
        assert!(h.depth() >= 1);
        let coarsest = h.coarsest(&g);
        // The natural floor of cluster coarsening is ≈ c(V)/W ≈ f·k
        // clusters; assert at least a 4x shrink on a web-like graph
        // (one level of matching could only give 2x).
        assert!(coarsest.n() * 4 < g.n(), "coarsest n = {}", coarsest.n());
        assert_eq!(coarsest.total_node_weight(), g.total_node_weight());
        assert!(coarsest.validate().is_ok());
    }

    #[test]
    fn cluster_beats_matching_shrink_rate() {
        // The paper's headline coarsening claim, in miniature.
        let mut rng = Rng::new(3);
        let g = crate::graph::subgraph::largest_component(&generators::rmat(
            12, 20000, 0.57, 0.19, 0.19, &mut rng,
        ));
        let cp = CoarseningParams::new(4, 0.03, cluster_scheme());
        let hc = coarsen(&g, &cp, None, &mut Rng::new(4));
        let mp = CoarseningParams::new(
            4,
            0.03,
            CoarseningScheme::Matching { two_hop: true },
        );
        let hm = coarsen(&g, &mp, None, &mut Rng::new(4));
        let first_cluster = hc.levels.first().map(|l| l.graph.n()).unwrap_or(g.n());
        let first_match = hm.levels.first().map(|l| l.graph.n()).unwrap_or(g.n());
        assert!(
            first_cluster * 2 < first_match,
            "cluster {} vs matching {}",
            first_cluster,
            first_match
        );
    }

    #[test]
    fn parallel_lpa_coarsening_is_thread_invariant() {
        let mut rng = Rng::new(10);
        let g = generators::barabasi_albert(4000, 4, &mut rng);
        let run = |threads: usize| {
            let mut params = CoarseningParams::new(4, 0.03, cluster_scheme());
            params.parallel_lpa = true;
            params.ctx = Some(Arc::new(ExecutionCtx::new(threads)));
            let h = coarsen(&g, &params, None, &mut Rng::new(11));
            h.levels
                .iter()
                .map(|l| l.map.clone())
                .collect::<Vec<_>>()
        };
        let reference = run(1);
        assert!(!reference.is_empty(), "no coarsening happened");
        for threads in [2usize, 4] {
            assert_eq!(reference, run(threads), "threads={threads} diverged");
        }
    }

    #[test]
    fn parallel_lpa_without_ctx_falls_back_sequentially() {
        // No ctx supplied: the flag must still select the same algorithm
        // (inline sequential context), with identical output.
        let mut rng = Rng::new(12);
        let g = generators::barabasi_albert(3000, 4, &mut rng);
        let mut without = CoarseningParams::new(4, 0.03, cluster_scheme());
        without.parallel_lpa = true;
        let mut with = CoarseningParams::new(4, 0.03, cluster_scheme());
        with.parallel_lpa = true;
        with.ctx = Some(Arc::new(ExecutionCtx::new(4)));
        let a = coarsen(&g, &without, None, &mut Rng::new(13));
        let b = coarsen(&g, &with, None, &mut Rng::new(13));
        assert_eq!(a.depth(), b.depth());
        for (la, lb) in a.levels.iter().zip(&b.levels) {
            assert_eq!(la.map, lb.map);
        }
    }

    #[test]
    fn small_graph_not_coarsened() {
        let g = crate::graph::karate_club();
        let params = CoarseningParams::new(2, 0.03, cluster_scheme());
        let h = coarsen(&g, &params, None, &mut Rng::new(5));
        assert_eq!(h.depth(), 0); // 34 < 120 threshold
        assert_eq!(h.coarsest(&g).n(), 34);
    }

    #[test]
    fn respect_projects_partition() {
        let mut rng = Rng::new(6);
        let g = generators::barabasi_albert(3000, 4, &mut rng);
        // arbitrary 2-partition by parity
        let part: Vec<u32> = (0..g.n() as u32).map(|v| v % 2).collect();
        let mut params = CoarseningParams::new(2, 0.03, cluster_scheme());
        params.max_levels = 3;
        let h = coarsen(&g, &params, Some(&part), &mut Rng::new(7));
        let coarsest = h.coarsest(&g);
        let coarse_part = h.coarsest_partition.as_ref().expect("partition projected");
        assert_eq!(coarse_part.len(), coarsest.n());
        // cut preserved exactly through all levels
        let fine_cut: Weight = g
            .edges()
            .filter(|&(u, v, _)| part[u as usize] != part[v as usize])
            .map(|(_, _, w)| w)
            .sum();
        let coarse_cut: Weight = coarsest
            .edges()
            .filter(|&(u, v, _)| coarse_part[u as usize] != coarse_part[v as usize])
            .map(|(_, _, w)| w)
            .sum();
        assert_eq!(fine_cut, coarse_cut);
    }

    #[test]
    fn matching_scheme_respects_blocks_too() {
        let mut rng = Rng::new(8);
        let g = generators::erdos_renyi(500, 2000, &mut rng);
        let part: Vec<u32> = (0..g.n() as u32).map(|v| v % 2).collect();
        let params = CoarseningParams::new(
            2,
            0.03,
            CoarseningScheme::Matching { two_hop: true },
        );
        let h = coarsen(&g, &params, Some(&part), &mut Rng::new(9));
        if let Some(cp) = &h.coarsest_partition {
            assert_eq!(cp.len(), h.coarsest(&g).n());
        }
    }
}
