//! Matching-based coarsening — the baseline the paper improves upon
//! (KaFFPa's scheme, and the kMetis 5.1 variant with 2-hop matching).
//!
//! Heavy-edge matching (HEM): visit nodes in random order; match each
//! unmatched node to the unmatched neighbor with maximum edge weight
//! (subject to the combined weight bound). The 2-hop extension matches
//! remaining unmatched nodes that *share a neighbor* (kMetis 5.1 added
//! this to improve coarsening on social networks — §5.1 of the paper).

use crate::clustering::label_propagation::Clustering;
use crate::graph::csr::{Graph, NodeId, Weight};
use crate::util::rng::Rng;

/// Compute a heavy-edge matching and return it as a clustering (pairs
/// and unmatched singletons), ready for [`super::contract::contract`].
pub fn heavy_edge_matching(
    g: &Graph,
    max_cluster_weight: Weight,
    two_hop: bool,
    rng: &mut Rng,
) -> Clustering {
    let n = g.n();
    const UNMATCHED: u32 = u32::MAX;
    let mut mate: Vec<u32> = vec![UNMATCHED; n];
    let mut order: Vec<NodeId> = g.nodes().collect();
    rng.shuffle(&mut order);

    for &v in &order {
        if mate[v as usize] != UNMATCHED {
            continue;
        }
        let vw = g.node_weight(v);
        let adj = g.adjacent(v);
        let ws = g.adjacent_weights(v);
        let mut best: Option<NodeId> = None;
        let mut best_w: Weight = Weight::MIN;
        for i in 0..adj.len() {
            let u = adj[i];
            if mate[u as usize] != UNMATCHED {
                continue;
            }
            if vw + g.node_weight(u) > max_cluster_weight {
                continue;
            }
            if ws[i] > best_w {
                best_w = ws[i];
                best = Some(u);
            }
        }
        if let Some(u) = best {
            mate[v as usize] = u;
            mate[u as usize] = v;
        }
    }

    if two_hop {
        // Match remaining singletons that share a neighbor. One pass:
        // for each still-unmatched v, scan neighbors' adjacency for an
        // unmatched 2-hop partner. Bounded scan to stay near-linear.
        for &v in &order {
            if mate[v as usize] != UNMATCHED {
                continue;
            }
            let vw = g.node_weight(v);
            let mut found: Option<NodeId> = None;
            'outer: for &u in g.adjacent(v) {
                // limit the per-neighbor scan on huge hubs
                for &w in g.adjacent(u).iter().take(64) {
                    if w != v
                        && mate[w as usize] == UNMATCHED
                        && vw + g.node_weight(w) <= max_cluster_weight
                    {
                        found = Some(w);
                        break 'outer;
                    }
                }
            }
            if let Some(w) = found {
                mate[v as usize] = w;
                mate[w as usize] = v;
            }
        }
    }

    // Matching → labels: each pair gets the smaller endpoint's id.
    let mut labels: Vec<u32> = vec![0; n];
    for v in 0..n as u32 {
        labels[v as usize] = if mate[v as usize] != UNMATCHED {
            v.min(mate[v as usize])
        } else {
            v
        };
    }
    Clustering::from_labels(g, labels)
}

/// Verify the matching property: every cluster has ≤ 2 nodes.
pub fn is_matching(c: &Clustering) -> bool {
    let mut counts = vec![0u32; c.num_clusters];
    for &l in &c.labels {
        counts[l as usize] += 1;
    }
    counts.iter().all(|&x| x <= 2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::karate::karate_club;

    #[test]
    fn hem_is_a_matching() {
        let g = karate_club();
        let mut rng = Rng::new(1);
        let c = heavy_edge_matching(&g, 4, false, &mut rng);
        assert!(is_matching(&c));
        assert!(c.respects_bound(4));
    }

    #[test]
    fn hem_prefers_heavy_edges() {
        // Path 0 -5- 1 -1- 2 -5- 3 : optimal HEM matches {0,1} and {2,3}.
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 5);
        b.add_edge(1, 2, 1);
        b.add_edge(2, 3, 5);
        let g = b.build();
        for seed in 0..10 {
            let mut rng = Rng::new(seed);
            let c = heavy_edge_matching(&g, 10, false, &mut rng);
            assert_eq!(c.labels[0], c.labels[1], "seed {seed}");
            assert_eq!(c.labels[2], c.labels[3], "seed {seed}");
        }
    }

    #[test]
    fn two_hop_matches_star_leaves() {
        // Star: hub 0 with 6 leaves. Plain HEM matches hub+one leaf and
        // leaves 5 singletons; 2-hop pairs up the leaves.
        let mut b = GraphBuilder::new(7);
        for v in 1..7u32 {
            b.add_edge(0, v, 1);
        }
        let g = b.build();
        let mut rng = Rng::new(3);
        let plain = heavy_edge_matching(&g, 4, false, &mut rng);
        let hop = heavy_edge_matching(&g, 4, true, &mut Rng::new(3));
        assert!(hop.num_clusters < plain.num_clusters);
        assert!(is_matching(&hop));
    }

    #[test]
    fn respects_weight_bound() {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 10);
        b.add_edge(2, 3, 10);
        b.set_node_weight(0, 3);
        b.set_node_weight(1, 3);
        let g = b.build();
        let mut rng = Rng::new(4);
        let c = heavy_edge_matching(&g, 4, true, &mut rng);
        // nodes 0,1 are too heavy to pair (3+3 > 4)
        assert_ne!(c.labels[0], c.labels[1]);
        assert_eq!(c.labels[2], c.labels[3]);
    }

    #[test]
    fn matching_on_complex_network_shrinks_slowly() {
        // This is the paper's core observation: matchings shrink
        // scale-free graphs by well under 2x per level, while cluster
        // contraction collapses them (compared in tests/properties.rs).
        let mut rng = Rng::new(5);
        let g = generators::rmat(11, 8000, 0.57, 0.19, 0.19, &mut rng);
        let c = heavy_edge_matching(&g, 100, false, &mut Rng::new(6));
        assert!(is_matching(&c));
        // shrink factor at most 2 by definition
        assert!(c.num_clusters * 2 >= g.n());
    }
}
