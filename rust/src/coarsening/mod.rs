//! Coarsening phase: cluster contraction (the paper's contribution),
//! the matching baseline, and hierarchy construction.

pub mod contract;
pub mod hierarchy;
pub mod matching;

pub use contract::{
    contract, contract_leased, contract_parallel, contract_parallel_ws, contract_store,
    contract_store_with_ctx, contract_with_ctx, contract_with_pool, project_partition,
    Contraction,
};
pub use hierarchy::{
    coarsen, coarsest_size_threshold, l_max, CoarseningParams, CoarseningScheme, Hierarchy,
    Level,
};
pub use matching::heavy_edge_matching;
