//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! on the CPU PJRT client (the `xla` crate). This is the only bridge
//! between the rust request path and the JAX/Pallas build-time world —
//! python never runs here.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled artifact: one `lpa_round` executable at a fixed (N, C).
pub struct CompiledRound {
    pub name: String,
    pub n: usize,
    pub c: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// Output of one offloaded LPA round.
#[derive(Debug, Clone)]
pub struct RoundOutput {
    /// Strongest eligible cluster per node (length N, padded).
    pub best: Vec<i32>,
    /// Connection gain vs staying (length N, padded).
    pub gain: Vec<f32>,
}

impl CompiledRound {
    /// Execute one synchronous SCLaP round.
    ///
    /// * `adj` — row-major N×N f32 adjacency (zero padded)
    /// * `labels` — i32[N] current cluster per node (in `[0, C)`)
    /// * `sizes` — f32[C] cluster weights snapshot
    /// * `node_w` — f32[N] node weights (0 for padding)
    /// * `upper` — size bound U
    pub fn execute(
        &self,
        adj: &[f32],
        labels: &[i32],
        sizes: &[f32],
        node_w: &[f32],
        upper: f32,
    ) -> Result<RoundOutput> {
        let (n, c) = (self.n, self.c);
        anyhow::ensure!(adj.len() == n * n, "adj size {} != {n}x{n}", adj.len());
        anyhow::ensure!(labels.len() == n && node_w.len() == n && sizes.len() == c);

        let adj_lit = xla::Literal::vec1(adj).reshape(&[n as i64, n as i64])?;
        let labels_lit = xla::Literal::vec1(labels);
        let sizes_lit = xla::Literal::vec1(sizes);
        let node_w_lit = xla::Literal::vec1(node_w);
        let upper_lit = xla::Literal::scalar(upper);

        let result = self
            .exe
            .execute::<xla::Literal>(&[adj_lit, labels_lit, sizes_lit, node_w_lit, upper_lit])?
            [0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True → (best, gain)
        let (best_lit, gain_lit) = result.to_tuple2()?;
        Ok(RoundOutput {
            best: best_lit.to_vec::<i32>()?,
            gain: gain_lit.to_vec::<f32>()?,
        })
    }
}

/// Artifact registry + PJRT client. Compiles HLO text lazily and caches
/// one executable per artifact.
pub struct Runtime {
    client: xla::PjRtClient,
    /// name → (n, c, path)
    manifest: Vec<(String, usize, usize, PathBuf)>,
    compiled: HashMap<String, std::rc::Rc<CompiledRound>>,
}

impl Runtime {
    /// Create a CPU PJRT runtime over an artifact directory produced by
    /// `make artifacts` (must contain `manifest.txt`).
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest_path = artifact_dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let mut manifest = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let tok: Vec<&str> = line.split_whitespace().collect();
            anyhow::ensure!(tok.len() == 4, "bad manifest line: {line}");
            manifest.push((
                tok[0].to_string(),
                tok[1].parse::<usize>()?,
                tok[2].parse::<usize>()?,
                artifact_dir.join(tok[3]),
            ));
        }
        anyhow::ensure!(!manifest.is_empty(), "empty artifact manifest");
        manifest.sort_by_key(|(_, n, _, _)| *n);
        Ok(Runtime {
            client,
            manifest,
            compiled: HashMap::new(),
        })
    }

    /// Default artifact directory: `$SCLAP_ARTIFACTS` or `./artifacts`.
    pub fn from_env() -> Result<Self> {
        let dir = std::env::var("SCLAP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::new(Path::new(&dir))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Largest artifact N available.
    pub fn max_n(&self) -> usize {
        self.manifest.iter().map(|(_, n, _, _)| *n).max().unwrap_or(0)
    }

    /// Pick the smallest artifact with `N >= n_needed` and compile it
    /// (cached). Returns None if no artifact is large enough.
    pub fn round_for(&mut self, n_needed: usize) -> Result<Option<std::rc::Rc<CompiledRound>>> {
        let Some((name, n, c, path)) = self
            .manifest
            .iter()
            .find(|(_, n, _, _)| *n >= n_needed)
            .cloned()
        else {
            return Ok(None);
        };
        if let Some(r) = self.compiled.get(&name) {
            return Ok(Some(r.clone()));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let round = std::rc::Rc::new(CompiledRound {
            name: name.clone(),
            n,
            c,
            exe,
        });
        self.compiled.insert(name, round.clone());
        Ok(Some(round))
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("platform", &self.platform())
            .field("artifacts", &self.manifest.len())
            .field("compiled", &self.compiled.len())
            .finish()
    }
}
