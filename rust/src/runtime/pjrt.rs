//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! on the CPU PJRT client. This is the only bridge between the rust
//! request path and the JAX/Pallas build-time world — python never runs
//! here.
//!
//! The real backend needs the `xla` crate, which is not available in
//! the offline build image, so it sits behind the `pjrt` cargo feature
//! (see Cargo.toml). The default build ships a std-only stub with the
//! same API: [`Runtime::new`] reports the backend as unavailable, every
//! offload entry point degrades gracefully (`round_for` → `Ok(None)`),
//! and `rust/tests/runtime_offload.rs` skips. The dense-LPA *semantics*
//! remain fully tested through `clustering::parallel_lpa`, which shares
//! the reconciliation path.

/// Output of one offloaded LPA round.
#[derive(Debug, Clone)]
pub struct RoundOutput {
    /// Strongest eligible cluster per node (length N, padded).
    pub best: Vec<i32>,
    /// Connection gain vs staying (length N, padded).
    pub gain: Vec<f32>,
}

// The real backend needs the `xla` crate, which is not declared in
// Cargo.toml (no offline registry). Turn the otherwise-confusing
// unresolved-import errors into one actionable diagnostic; delete this
// guard after vendoring `xla` as a dependency.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires a vendored `xla` crate: add it to \
     [dependencies] in rust/Cargo.toml and remove this compile_error! \
     (see the feature's note in Cargo.toml)"
);

#[cfg(feature = "pjrt")]
mod backend {
    //! Real XLA-backed implementation. Compiled only with
    //! `--features pjrt`, which requires vendoring the `xla` crate.

    use super::RoundOutput;
    use crate::util::error::{Context, Error, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    impl From<xla::Error> for Error {
        fn from(e: xla::Error) -> Self {
            Error::msg(format!("xla: {e}"))
        }
    }

    /// A compiled artifact: one `lpa_round` executable at a fixed (N, C).
    pub struct CompiledRound {
        pub name: String,
        pub n: usize,
        pub c: usize,
        exe: xla::PjRtLoadedExecutable,
    }

    impl CompiledRound {
        /// Execute one synchronous SCLaP round.
        ///
        /// * `adj` — row-major N×N f32 adjacency (zero padded)
        /// * `labels` — i32[N] current cluster per node (in `[0, C)`)
        /// * `sizes` — f32[C] cluster weights snapshot
        /// * `node_w` — f32[N] node weights (0 for padding)
        /// * `upper` — size bound U
        pub fn execute(
            &self,
            adj: &[f32],
            labels: &[i32],
            sizes: &[f32],
            node_w: &[f32],
            upper: f32,
        ) -> Result<RoundOutput> {
            let (n, c) = (self.n, self.c);
            crate::ensure!(adj.len() == n * n, "adj size {} != {n}x{n}", adj.len());
            crate::ensure!(
                labels.len() == n && node_w.len() == n && sizes.len() == c,
                "input shapes do not match artifact (N={n}, C={c})"
            );

            let adj_lit = xla::Literal::vec1(adj).reshape(&[n as i64, n as i64])?;
            let labels_lit = xla::Literal::vec1(labels);
            let sizes_lit = xla::Literal::vec1(sizes);
            let node_w_lit = xla::Literal::vec1(node_w);
            let upper_lit = xla::Literal::scalar(upper);

            let result = self
                .exe
                .execute::<xla::Literal>(&[adj_lit, labels_lit, sizes_lit, node_w_lit, upper_lit])?
                [0][0]
                .to_literal_sync()?;
            // aot.py lowers with return_tuple=True → (best, gain)
            let (best_lit, gain_lit) = result.to_tuple2()?;
            Ok(RoundOutput {
                best: best_lit.to_vec::<i32>()?,
                gain: gain_lit.to_vec::<f32>()?,
            })
        }
    }

    /// Artifact registry + PJRT client. Compiles HLO text lazily and
    /// caches one executable per artifact.
    pub struct Runtime {
        client: xla::PjRtClient,
        /// name → (n, c, path)
        manifest: Vec<(String, usize, usize, PathBuf)>,
        compiled: HashMap<String, std::rc::Rc<CompiledRound>>,
    }

    impl Runtime {
        /// Create a CPU PJRT runtime over an artifact directory produced
        /// by `make artifacts` (must contain `manifest.txt`).
        pub fn new(artifact_dir: &Path) -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let manifest_path = artifact_dir.join("manifest.txt");
            let text = std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading {}", manifest_path.display()))?;
            let mut manifest = Vec::new();
            for line in text.lines() {
                let line = line.trim();
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                let tok: Vec<&str> = line.split_whitespace().collect();
                crate::ensure!(tok.len() == 4, "bad manifest line: {line}");
                manifest.push((
                    tok[0].to_string(),
                    tok[1].parse::<usize>()?,
                    tok[2].parse::<usize>()?,
                    artifact_dir.join(tok[3]),
                ));
            }
            crate::ensure!(!manifest.is_empty(), "empty artifact manifest");
            manifest.sort_by_key(|(_, n, _, _)| *n);
            Ok(Runtime {
                client,
                manifest,
                compiled: HashMap::new(),
            })
        }

        /// Default artifact directory: `$SCLAP_ARTIFACTS` or `./artifacts`.
        pub fn from_env() -> Result<Self> {
            let dir = std::env::var("SCLAP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
            Self::new(Path::new(&dir))
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Largest artifact N available.
        pub fn max_n(&self) -> usize {
            self.manifest.iter().map(|(_, n, _, _)| *n).max().unwrap_or(0)
        }

        /// Pick the smallest artifact with `N >= n_needed` and compile
        /// it (cached). Returns None if no artifact is large enough.
        pub fn round_for(&mut self, n_needed: usize) -> Result<Option<std::rc::Rc<CompiledRound>>> {
            let Some((name, n, c, path)) = self
                .manifest
                .iter()
                .find(|(_, n, _, _)| *n >= n_needed)
                .cloned()
            else {
                return Ok(None);
            };
            if let Some(r) = self.compiled.get(&name) {
                return Ok(Some(r.clone()));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            let round = std::rc::Rc::new(CompiledRound {
                name: name.clone(),
                n,
                c,
                exe,
            });
            self.compiled.insert(name, round.clone());
            Ok(Some(round))
        }
    }

    impl std::fmt::Debug for Runtime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Runtime")
                .field("platform", &self.platform())
                .field("artifacts", &self.manifest.len())
                .field("compiled", &self.compiled.len())
                .finish()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    //! Std-only stub: same API surface, constructor always reports the
    //! backend as unavailable. No `Runtime` instance can exist, so the
    //! other methods only need to type-check the call sites.

    use super::RoundOutput;
    use crate::util::error::{Error, Result};
    use std::path::Path;

    /// Stub artifact handle (never constructed without the backend).
    pub struct CompiledRound {
        pub name: String,
        pub n: usize,
        pub c: usize,
    }

    impl CompiledRound {
        pub fn execute(
            &self,
            _adj: &[f32],
            _labels: &[i32],
            _sizes: &[f32],
            _node_w: &[f32],
            _upper: f32,
        ) -> Result<RoundOutput> {
            Err(Error::msg("PJRT backend unavailable (built without the `pjrt` feature)"))
        }
    }

    /// Stub runtime: [`Runtime::new`] always fails with a diagnostic.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn new(artifact_dir: &Path) -> Result<Self> {
            Err(Error::msg(format!(
                "PJRT backend unavailable: sclap was built without the `pjrt` cargo \
                 feature (artifact dir {}); the offline image has no `xla` crate — \
                 see Cargo.toml",
                artifact_dir.display()
            )))
        }

        pub fn from_env() -> Result<Self> {
            let dir = std::env::var("SCLAP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
            Self::new(Path::new(&dir))
        }

        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        pub fn max_n(&self) -> usize {
            0
        }

        pub fn round_for(&mut self, _n_needed: usize) -> Result<Option<std::rc::Rc<CompiledRound>>> {
            Ok(None)
        }
    }

    impl std::fmt::Debug for Runtime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Runtime")
                .field("platform", &"unavailable (stub)")
                .finish()
        }
    }
}

pub use backend::{CompiledRound, Runtime};

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = Runtime::new(std::path::Path::new("artifacts")).unwrap_err();
        let text = format!("{err}");
        assert!(text.contains("pjrt"), "{text}");
        assert!(Runtime::from_env().is_err());
    }
}
