//! PJRT runtime: loads `artifacts/*.hlo.txt` (AOT-lowered by
//! `python/compile/aot.py`) and executes the dense synchronous SCLaP
//! round from the rust request path. Python never runs here.

pub mod dense_lpa;
pub mod pjrt;

pub use dense_lpa::{offload_sclap, pack_dense, OffloadStats};
pub use pjrt::{CompiledRound, RoundOutput, Runtime};
