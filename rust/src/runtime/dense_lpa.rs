//! Dense-LPA offload: run SCLaP scoring rounds through the AOT-compiled
//! JAX/Pallas artifact and reconcile the synchronous proposals on the
//! host (DESIGN.md §Hardware-Adaptation).
//!
//! Applicability: the *coarse* levels of the hierarchy. After one
//! cluster contraction a web graph is orders of magnitude smaller
//! (paper §5.2), so the N ≤ 1024 artifact shapes cover the levels where
//! clustering quality matters most per node.

use crate::util::error::Result;

use crate::clustering::label_propagation::Clustering;
use crate::clustering::parallel_lpa::{reconcile_proposals, Proposal};
use crate::graph::csr::{Graph, Weight};
use super::pjrt::Runtime;

/// Outcome statistics of an offloaded clustering.
#[derive(Debug, Clone)]
pub struct OffloadStats {
    pub rounds: usize,
    pub proposals: usize,
    pub applied: usize,
    pub artifact_n: usize,
}

/// Pack a graph into the dense row-major f32 adjacency the artifact
/// expects (zero-padded to `n_pad`).
pub fn pack_dense(g: &Graph, n_pad: usize) -> Vec<f32> {
    assert!(g.n() <= n_pad);
    let mut adj = vec![0f32; n_pad * n_pad];
    for v in g.nodes() {
        let row = v as usize * n_pad;
        let targets = g.adjacent(v);
        let ws = g.adjacent_weights(v);
        for i in 0..targets.len() {
            adj[row + targets[i] as usize] = ws[i] as f32;
        }
    }
    adj
}

/// Size-constrained clustering via offloaded synchronous rounds.
///
/// Semantics match `clustering::parallel_lpa::parallel_sclap`: each
/// round scores *all* nodes against a snapshot (on the PJRT executable),
/// then proposals are applied in descending-gain order against a live
/// size table so the constraint `cluster weight ≤ upper` holds exactly.
///
/// Returns `Ok(None)` if no artifact is large enough for `g`.
pub fn offload_sclap(
    g: &Graph,
    upper: Weight,
    max_rounds: usize,
    runtime: &mut Runtime,
) -> Result<Option<(Clustering, OffloadStats)>> {
    let n = g.n();
    let Some(round) = runtime.round_for(n)? else {
        return Ok(None);
    };
    let n_pad = round.n;
    assert_eq!(round.c, n_pad, "cluster artifacts are square");

    let adj = pack_dense(g, n_pad);
    // Padding nodes: weight 0, singleton labels beyond the real range —
    // they never produce positive gain (tested in python/tests).
    let mut labels_i32: Vec<i32> = (0..n_pad as i32).collect();
    let mut node_w: Vec<f32> = vec![0.0; n_pad];
    for v in g.nodes() {
        node_w[v as usize] = g.node_weight(v) as f32;
    }
    let mut sizes: Vec<f32> = vec![0.0; n_pad];
    for v in g.nodes() {
        sizes[labels_i32[v as usize] as usize] += g.node_weight(v) as f32;
    }

    let mut labels: Vec<u32> = (0..n as u32).collect();
    let mut cluster_weight: Vec<Weight> = g.node_weights().to_vec();
    cluster_weight.resize(n_pad, 0);

    let mut stats = OffloadStats {
        rounds: 0,
        proposals: 0,
        applied: 0,
        artifact_n: n_pad,
    };

    for _ in 0..max_rounds {
        stats.rounds += 1;
        let out = round.execute(&adj, &labels_i32, &sizes, &node_w, upper as f32)?;
        let mut proposals: Vec<Proposal> = Vec::new();
        for v in 0..n {
            // f32 gains are exact for integer edge weights < 2^24.
            if out.gain[v] > 0.0 {
                proposals.push(Proposal {
                    node: v as u32,
                    target: out.best[v] as u32,
                    gain: out.gain[v] as i64,
                });
            }
        }
        stats.proposals += proposals.len();
        let applied =
            reconcile_proposals(g, &mut labels, &mut cluster_weight, upper, &mut proposals);
        stats.applied += applied;
        // Refresh device inputs from the reconciled state.
        for v in 0..n {
            labels_i32[v] = labels[v] as i32;
        }
        for s in sizes.iter_mut() {
            *s = 0.0;
        }
        for v in 0..n {
            sizes[labels[v] as usize] += node_w[v];
        }
        if (applied as f64) < 0.05 * n as f64 {
            break;
        }
    }

    let clustering = Clustering::from_labels(g, labels);
    Ok(Some((clustering, stats)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    #[test]
    fn pack_dense_symmetric() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1, 2);
        b.add_edge(1, 2, 5);
        let g = b.build();
        let adj = pack_dense(&g, 4);
        assert_eq!(adj.len(), 16);
        assert_eq!(adj[0 * 4 + 1], 2.0);
        assert_eq!(adj[1 * 4 + 0], 2.0);
        assert_eq!(adj[1 * 4 + 2], 5.0);
        assert_eq!(adj[2 * 4 + 1], 5.0);
        // diagonal and padding are zero
        assert_eq!(adj[0], 0.0);
        assert_eq!(adj[3 * 4 + 3], 0.0);
        assert_eq!(adj[0 * 4 + 3], 0.0);
    }

    // Execution tests live in rust/tests/runtime_offload.rs (they need
    // the artifacts built by `make artifacts`).
}
