"""L2 correctness: the dense synchronous SCLaP round.

Checks the jnp model against (a) the jnp reference and (b) an
independent loop-based numpy oracle, plus the semantic properties the
rust reconciliation relies on (eligibility, own-cluster always legal,
gain sign).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import lpa_round_numpy, lpa_round_ref
from compile.model import lpa_round

jax.config.update("jax_platform_name", "cpu")


def random_instance(seed, n, c=None, density=0.3):
    rng = np.random.default_rng(seed)
    c = c or n
    adj = (rng.random((n, n)) < density).astype(np.float32)
    adj = np.triu(adj, 1)
    adj = adj + adj.T
    labels = rng.integers(0, c, size=n).astype(np.int32)
    node_w = rng.integers(1, 4, size=n).astype(np.float32)
    sizes = np.zeros(c, dtype=np.float32)
    for v in range(n):
        sizes[labels[v]] += node_w[v]
    upper = np.float32(max(node_w.max(), sizes.max() * 0.8))
    return adj, labels, sizes, node_w, upper


@pytest.mark.parametrize("n", [4, 8, 16, 32, 64])
def test_model_matches_jnp_ref(n):
    adj, labels, sizes, node_w, upper = random_instance(n, n)
    got = lpa_round(*map(jnp.asarray, (adj, labels, sizes, node_w, upper)))
    want = lpa_round_ref(*map(jnp.asarray, (adj, labels, sizes, node_w, upper)))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_model_matches_numpy_oracle(n, seed):
    adj, labels, sizes, node_w, upper = random_instance(seed, n)
    best, gain = lpa_round(*map(jnp.asarray, (adj, labels, sizes, node_w, upper)))
    nb, ng = lpa_round_numpy(adj, labels, sizes, node_w, upper)
    np.testing.assert_array_equal(np.asarray(best), nb)
    np.testing.assert_allclose(np.asarray(gain), ng, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_proposed_targets_are_eligible(seed):
    """Every proposed move with positive gain targets a cluster that has
    room — the invariant the rust host-side reconciliation starts from."""
    adj, labels, sizes, node_w, upper = random_instance(seed, 20)
    best, gain = map(
        np.asarray, lpa_round(*map(jnp.asarray, (adj, labels, sizes, node_w, upper)))
    )
    for v in range(20):
        if gain[v] > 0:
            assert best[v] != labels[v]
            assert sizes[best[v]] + node_w[v] <= upper + 1e-6


def test_own_cluster_always_allowed():
    """A node whose every neighbor cluster is full must stay (gain 0)."""
    n = 4
    adj = np.zeros((n, n), np.float32)
    adj[0, 1] = adj[1, 0] = 5.0
    labels = np.array([0, 1, 2, 3], np.int32)
    node_w = np.ones(n, np.float32)
    sizes = np.array([1, 1, 1, 1], np.float32)
    upper = np.float32(1.0)  # nothing has room
    best, gain = map(
        np.asarray,
        lpa_round(*map(jnp.asarray, (adj, labels, sizes, node_w, upper))),
    )
    assert best[0] == 0
    assert gain[0] <= 0


def test_strongest_cluster_wins():
    """Node 0 connects with weight 1 to cluster 1 and weight 3 to
    cluster 2: the proposal must be cluster 2."""
    n = 4
    adj = np.zeros((n, n), np.float32)
    adj[0, 1] = adj[1, 0] = 1.0
    adj[0, 2] = adj[2, 0] = 3.0
    labels = np.array([0, 1, 2, 2], np.int32)
    node_w = np.ones(n, np.float32)
    sizes = np.array([1, 1, 2, 0], np.float32)
    upper = np.float32(10.0)
    best, gain = map(
        np.asarray,
        lpa_round(*map(jnp.asarray, (adj, labels, sizes, node_w, upper))),
    )
    assert best[0] == 2
    assert gain[0] == 3.0  # stay-score is 0 (no neighbor in cluster 0)


def test_size_constraint_blocks_strongest():
    """The strongest cluster is full: the proposal falls back to the
    next-best eligible one."""
    n = 4
    adj = np.zeros((n, n), np.float32)
    adj[0, 1] = adj[1, 0] = 1.0
    adj[0, 2] = adj[2, 0] = 3.0
    labels = np.array([0, 1, 2, 2], np.int32)
    node_w = np.ones(n, np.float32)
    sizes = np.array([1, 1, 2, 0], np.float32)
    upper = np.float32(2.0)  # cluster 2 (size 2) has no room for w=1
    best, gain = map(
        np.asarray,
        lpa_round(*map(jnp.asarray, (adj, labels, sizes, node_w, upper))),
    )
    assert best[0] == 1
    assert gain[0] == 1.0


def test_isolated_node_never_moves():
    n = 3
    adj = np.zeros((n, n), np.float32)
    labels = np.array([0, 1, 2], np.int32)
    node_w = np.ones(n, np.float32)
    sizes = np.ones(3, np.float32)
    upper = np.float32(10.0)
    best, gain = map(
        np.asarray,
        lpa_round(*map(jnp.asarray, (adj, labels, sizes, node_w, upper))),
    )
    assert (gain <= 0).all()


def test_padding_rows_inert():
    """Zero-padded rows (the runtime pads graphs to the artifact shape)
    must produce non-positive gain so the host never applies them."""
    n, real = 16, 5
    rng = np.random.default_rng(3)
    adj = np.zeros((n, n), np.float32)
    block = (rng.random((real, real)) < 0.6).astype(np.float32)
    block = np.triu(block, 1)
    adj[:real, :real] = block + block.T
    labels = np.arange(n, dtype=np.int32)
    node_w = np.ones(n, np.float32)
    sizes = np.ones(n, np.float32)
    upper = np.float32(4.0)
    best, gain = map(
        np.asarray,
        lpa_round(*map(jnp.asarray, (adj, labels, sizes, node_w, upper))),
    )
    assert (gain[real:] <= 0).all()
