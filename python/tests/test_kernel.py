"""L1 correctness: Pallas scoring kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (including ragged non-multiple-of-block sizes)
and dtypes; assert_allclose against ref.scoring_ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.lpa_kernel import (
    mxu_utilization_estimate,
    scoring_matmul,
    vmem_footprint_bytes,
)
from compile.kernels.ref import scoring_ref

jax.config.update("jax_platform_name", "cpu")


def random_problem(rng, n, c, density=0.3, dtype=np.float32):
    adj = (rng.random((n, n)) < density).astype(dtype)
    adj = np.triu(adj, 1)
    adj = adj + adj.T  # symmetric, zero diagonal
    labels = rng.integers(0, c, size=n).astype(np.int32)
    onehot = np.eye(c, dtype=dtype)[labels]
    return adj, onehot


@pytest.mark.parametrize("n,c", [(8, 8), (16, 4), (64, 64), (128, 128), (256, 256)])
def test_matches_ref_square_and_tall(n, c):
    rng = np.random.default_rng(n * 1000 + c)
    adj, onehot = random_problem(rng, n, c)
    out = scoring_matmul(jnp.asarray(adj), jnp.asarray(onehot))
    expected = scoring_ref(jnp.asarray(adj), jnp.asarray(onehot))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=160),
    c=st.integers(min_value=1, max_value=96),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_ragged_shapes(n, c, seed):
    """Shapes that are NOT multiples of the block size must still agree."""
    rng = np.random.default_rng(seed)
    adj, onehot = random_problem(rng, n, c, density=0.4)
    out = scoring_matmul(jnp.asarray(adj), jnp.asarray(onehot), block_n=32, block_c=32, block_k=32)
    expected = scoring_ref(jnp.asarray(adj), jnp.asarray(onehot))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    bn=st.sampled_from([8, 16, 32, 128]),
    bc=st.sampled_from([8, 16, 32, 128]),
    bk=st.sampled_from([8, 16, 32, 128]),
)
def test_hypothesis_block_shapes(bn, bc, bk):
    """Result must be invariant to the blocking schedule."""
    rng = np.random.default_rng(7)
    adj, onehot = random_problem(rng, 48, 48)
    out = scoring_matmul(
        jnp.asarray(adj), jnp.asarray(onehot), block_n=bn, block_c=bc, block_k=bk
    )
    expected = scoring_ref(jnp.asarray(adj), jnp.asarray(onehot))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=1e-6)


def test_weighted_edges():
    rng = np.random.default_rng(11)
    n, c = 32, 32
    adj = rng.random((n, n)).astype(np.float32) * 5
    adj = np.triu(adj, 1)
    adj = adj + adj.T
    labels = rng.integers(0, c, size=n).astype(np.int32)
    onehot = np.eye(c, dtype=np.float32)[labels]
    out = scoring_matmul(jnp.asarray(adj), jnp.asarray(onehot))
    np.testing.assert_allclose(
        np.asarray(out), adj @ onehot, rtol=1e-5, atol=1e-5
    )


def test_float64_dtype():
    rng = np.random.default_rng(13)
    adj, onehot = random_problem(rng, 24, 24, dtype=np.float32)
    # jax default f32; exercise explicit f32 (f64 needs jax_enable_x64,
    # not part of the AOT contract) — check dtype propagation instead.
    out = scoring_matmul(jnp.asarray(adj), jnp.asarray(onehot))
    assert out.dtype == jnp.float32


def test_zero_adjacency():
    n = 16
    adj = jnp.zeros((n, n), jnp.float32)
    onehot = jnp.eye(n, dtype=jnp.float32)
    out = scoring_matmul(adj, onehot)
    assert float(jnp.abs(out).max()) == 0.0


def test_jit_compatible():
    """The kernel must lower inside jit (the AOT path requires it)."""
    rng = np.random.default_rng(17)
    adj, onehot = random_problem(rng, 64, 64)
    f = jax.jit(lambda a, b: scoring_matmul(a, b))
    out = f(jnp.asarray(adj), jnp.asarray(onehot))
    np.testing.assert_allclose(np.asarray(out), adj @ onehot, rtol=1e-6)


def test_vmem_footprint_default_blocks():
    # 3 tiles of 128x128 f32 = 192 KiB << 16 MiB VMEM.
    assert vmem_footprint_bytes() == 3 * 128 * 128 * 4
    assert vmem_footprint_bytes() < 16 * 2**20 // 8


def test_mxu_utilization_power_of_two_is_full():
    assert mxu_utilization_estimate(512, 512) == 1.0
    assert mxu_utilization_estimate(1024, 1024) == 1.0
    # ragged shapes waste lanes
    assert mxu_utilization_estimate(130, 130) < 0.6
