"""AOT export path: lowering must produce loadable HLO text."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import export_shape, to_hlo_text
from compile.model import lpa_round, lpa_round_spec

jax.config.update("jax_platform_name", "cpu")


def test_lowering_produces_hlo_text(tmp_path):
    name = export_shape(128, 128, str(tmp_path))
    path = tmp_path / f"{name}.hlo.txt"
    assert path.exists()
    text = path.read_text()
    assert "HloModule" in text
    # pallas interpret-mode must lower to plain HLO, not custom-calls the
    # CPU PJRT cannot execute
    assert "mosaic" not in text.lower()
    assert len(text) > 1000


def test_hlo_text_round_trips_through_jit():
    """The lowered function must compute the same values as eager."""
    n = 32
    lowered = jax.jit(lpa_round).lower(*lpa_round_spec(n, n))
    compiled = lowered.compile()
    rng = np.random.default_rng(5)
    adj = (rng.random((n, n)) < 0.3).astype(np.float32)
    adj = np.triu(adj, 1)
    adj = adj + adj.T
    labels = np.arange(n, dtype=np.int32)
    sizes = np.ones(n, np.float32)
    node_w = np.ones(n, np.float32)
    upper = np.float32(8.0)
    got = compiled(adj, labels, sizes, node_w, upper)
    want = lpa_round(*map(jnp.asarray, (adj, labels, sizes, node_w, upper)))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]), rtol=1e-6)


def test_to_hlo_text_tuple_output():
    lowered = jax.jit(lpa_round).lower(*lpa_round_spec(16, 16))
    text = to_hlo_text(lowered)
    # return_tuple=True: the entry computation root is a tuple of 2
    assert "HloModule" in text
    assert "tuple(" in text.replace(" ", "")[:20000] or "tuple" in text


def test_manifest_written(tmp_path):
    from compile import aot

    # simulate main() for a tiny shape set
    old = aot.SHAPES
    try:
        aot.SHAPES = [(16, 16)]
        import sys

        argv = sys.argv
        sys.argv = ["aot", "--out", str(tmp_path)]
        try:
            aot.main()
        finally:
            sys.argv = argv
    finally:
        aot.SHAPES = old
    manifest = (tmp_path / "manifest.txt").read_text()
    assert "lpa_r16x16" in manifest
    assert os.path.exists(tmp_path / "lpa_r16x16.hlo.txt")
