"""AOT export: lower the L2 model to HLO text for the rust PJRT runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md.

Usage:   cd python && python -m compile.aot --out ../artifacts

Writes one `lpa_r{N}x{C}.hlo.txt` per exported shape plus `manifest.txt`
(`name n c filename` per line) which the rust artifact registry parses.
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile.model import lpa_round, lpa_round_spec

# Exported shapes: padded power-of-two rounds for coarse graphs. The
# rust runtime picks the smallest N >= graph size. C == N because during
# coarsening every node is a potential cluster.
SHAPES = [(128, 128), (256, 256), (512, 512), (1024, 1024)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_shape(n: int, c: int, out_dir: str) -> str:
    lowered = jax.jit(lpa_round).lower(*lpa_round_spec(n, c))
    text = to_hlo_text(lowered)
    name = f"lpa_r{n}x{c}"
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return name


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    parser.add_argument(
        "--shapes",
        default=None,
        help="comma-separated NxC list (default: %s)" % SHAPES,
    )
    args = parser.parse_args()
    shapes = SHAPES
    if args.shapes:
        shapes = [tuple(map(int, s.split("x"))) for s in args.shapes.split(",")]

    os.makedirs(args.out, exist_ok=True)
    lines = []
    for n, c in shapes:
        name = export_shape(n, c, args.out)
        lines.append(f"{name} {n} {c} {name}.hlo.txt")
        print(f"exported {name}")
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("# sclap AOT artifact manifest: name n c file\n")
        f.write("\n".join(lines) + "\n")
    print(f"wrote manifest with {len(lines)} artifacts to {args.out}")


if __name__ == "__main__":
    main()
