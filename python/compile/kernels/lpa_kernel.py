"""L1 — Pallas scoring kernel for the dense synchronous SCLaP round.

The hot spot of one label-propagation round over a graph with adjacency
``A`` (N×N, f32, zero-padded) and one-hot labels ``L`` (N×C) is the
cluster-connection score matrix

    S = A @ L            # S[v, c] = total edge weight from v into cluster c

which is exactly an MXU-shaped matmul. The paper's CPU implementation
does this with per-node hash scans; the TPU re-think (DESIGN.md
§Hardware-Adaptation) tiles A and L into VMEM-resident blocks with
BlockSpec and accumulates partial products over the K grid axis.

The kernel MUST be lowered with ``interpret=True`` here: the container's
CPU PJRT cannot execute Mosaic custom-calls. Block shapes are chosen for
the TPU MXU (128×128 systolic tiles); the §Perf section of
EXPERIMENTS.md estimates VMEM footprint and MXU utilization from these
shapes rather than from interpret-mode wallclock.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile edge. All artifact shapes are multiples of 128 (padding
# is the caller's job); tests exercise smaller odd shapes through the
# same code path with clamped block sizes.
DEFAULT_BLOCK = 128


def _matmul_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    """One (i, j, k) grid step: o[i,j] (+)= a[i,k] @ b[k,j].

    The K axis is the innermost sequential grid dimension; the output
    block is revisited for every k, so we zero it on the first visit and
    accumulate in place (the classic Pallas reduction pattern — on TPU
    the block stays resident in VMEM across the K loop).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def scoring_matmul(
    adj: jax.Array,
    onehot: jax.Array,
    *,
    block_n: int = DEFAULT_BLOCK,
    block_c: int = DEFAULT_BLOCK,
    block_k: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jax.Array:
    """Blocked S = adj @ onehot via Pallas.

    adj:    f32[N, K]   (square N==K for a full LPA round)
    onehot: f32[K, C]
    returns f32[N, C]

    N, K, C need not be multiples of the block sizes; blocks are clamped
    (Pallas masks the ragged edge in interpret mode and on TPU).
    """
    n, k_dim = adj.shape
    k2, c = onehot.shape
    assert k_dim == k2, f"inner dims mismatch: {adj.shape} @ {onehot.shape}"
    bn = min(block_n, n)
    bc = min(block_c, c)
    bk = min(block_k, k_dim)

    # Zero-pad ragged shapes up to block multiples: Pallas pads
    # out-of-bounds *input* tiles with undefined values (NaN in interpret
    # mode), and padded K-columns would otherwise poison valid outputs
    # through the accumulation. Explicit zero padding keeps the kernel
    # branch-free (no masks on the MXU path); artifact shapes are already
    # multiples so this is a no-op on the AOT path.
    np_ = -n % bn
    cp = -c % bc
    kp = -k_dim % bk
    a = jnp.pad(adj, ((0, np_), (0, kp))) if (np_ or kp) else adj
    b = jnp.pad(onehot, ((0, kp), (0, cp))) if (kp or cp) else onehot
    pn, pk = n + np_, k_dim + kp
    pc = c + cp
    grid = (pl.cdiv(pn, bn), pl.cdiv(pc, bc), pl.cdiv(pk, bk))

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bc), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bn, bc), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pn, pc), adj.dtype),
        interpret=interpret,
    )(a, b)
    return out[:n, :c] if (np_ or cp) else out


def vmem_footprint_bytes(
    block_n: int = DEFAULT_BLOCK,
    block_c: int = DEFAULT_BLOCK,
    block_k: int = DEFAULT_BLOCK,
    dtype_bytes: int = 4,
) -> int:
    """Resident VMEM bytes for one grid step (A-tile + B-tile + O-tile).

    Used by the §Perf analysis: with the default 128³ f32 blocking this
    is 3 · 128 · 128 · 4 = 192 KiB, far below the ~16 MiB VMEM of a TPU
    core, leaving room for double buffering (2× the A/B tiles).
    """
    return dtype_bytes * (block_n * block_k + block_k * block_c + block_n * block_c)


def mxu_utilization_estimate(
    n: int,
    c: int,
    block_n: int = DEFAULT_BLOCK,
    block_c: int = DEFAULT_BLOCK,
    block_k: int = DEFAULT_BLOCK,
) -> float:
    """Fraction of MXU-issue slots doing useful work for an N×N×C score
    matmul under the given blocking (1.0 = every 128×128×128 MXU pass is
    full). Ragged edges waste (block - n % block) lanes; for the
    power-of-two artifact shapes this returns 1.0.
    """
    import math

    full = n * n * c
    padded = (
        math.ceil(n / block_n) * block_n
        * math.ceil(n / block_k) * block_k
        * math.ceil(c / block_c) * block_c
    )
    return full / padded
