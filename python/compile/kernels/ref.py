"""Pure-jnp oracle for the Pallas scoring kernel and the LPA round.

Everything here is deliberately naive (no tiling, no fusion tricks): it
defines *correct* semantics that python/tests/ checks the optimized
kernel and the AOT-exported model against.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def scoring_ref(adj: jnp.ndarray, onehot: jnp.ndarray) -> jnp.ndarray:
    """Reference score matrix: plain jnp matmul."""
    return jnp.dot(adj, onehot)


def lpa_round_ref(adj, labels, sizes, node_w, upper):
    """Reference dense synchronous SCLaP round (see model.lpa_round).

    Returns (best, gain): for each node the strongest *eligible* cluster
    (its own cluster always eligible; ties -> lowest cluster id, matching
    jnp.argmax) and the connection-strength gain vs. staying.
    """
    c = sizes.shape[0]
    onehot = jnp.eye(c, dtype=adj.dtype)[labels]
    scores = scoring_ref(adj, onehot)
    eligible = (sizes[None, :] + node_w[:, None]) <= upper
    eligible = eligible | (onehot > 0)
    neg = jnp.asarray(jnp.finfo(adj.dtype).min / 2, adj.dtype)
    masked = jnp.where(eligible, scores, neg)
    best = jnp.argmax(masked, axis=1).astype(jnp.int32)
    stay = jnp.take_along_axis(scores, labels[:, None].astype(jnp.int32), axis=1)[:, 0]
    gain = jnp.max(masked, axis=1) - stay
    return best, gain


def lpa_round_numpy(adj, labels, sizes, node_w, upper):
    """Second, independent oracle in numpy with explicit loops — guards
    against a systematic mistake shared by the two jnp implementations."""
    n, _ = adj.shape
    c = sizes.shape[0]
    best = np.zeros(n, dtype=np.int32)
    gain = np.zeros(n, dtype=adj.dtype)
    for v in range(n):
        conn = np.zeros(c, dtype=np.float64)
        for u in range(n):
            if adj[v, u] != 0.0:
                conn[labels[u]] += float(adj[v, u])
        stay = conn[labels[v]]
        best_c, best_s = None, -np.inf
        for cc in range(c):
            ok = cc == labels[v] or (sizes[cc] + node_w[v]) <= upper
            if not ok:
                continue
            if conn[cc] > best_s:
                best_s, best_c = conn[cc], cc
        best[v] = best_c
        gain[v] = best_s - stay
    return best, gain
