"""L2 — the dense synchronous SCLaP round as a JAX compute graph.

Composes the L1 Pallas scoring kernel with the eligibility masking +
argmax of the paper's move rule (§3.1):

    move v to the eligible cluster with the strongest connection,

where *eligible* means the target stays within the size bound U (a
node's own cluster is always eligible — staying is legal). The
sequential-vs-synchronous adaptation and host-side reconciliation are
documented in DESIGN.md §Hardware-Adaptation; the rust side applies the
returned proposals in descending-gain order against a live size table.

This module is build-time only: `aot.py` lowers `lpa_round` to HLO text
once; rust executes the artifact via PJRT. Python never runs at request
time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.lpa_kernel import scoring_matmul


def lpa_round(adj, labels, sizes, node_w, upper):
    """One synchronous size-constrained LPA round.

    adj:    f32[N, N] symmetric weighted adjacency (0-padded)
    labels: i32[N]    current cluster per node, in [0, C)
    sizes:  f32[C]    current cluster weights (snapshot)
    node_w: f32[N]    node weights
    upper:  f32[]     size bound U

    Returns (best i32[N], gain f32[N]): the strongest eligible cluster
    per node and the connection gain vs. staying. gain <= 0 means "no
    improving move" (the host only applies strictly positive gains).
    """
    c = sizes.shape[0]
    onehot = jax.nn.one_hot(labels, c, dtype=adj.dtype)
    scores = scoring_matmul(adj, onehot)  # L1 Pallas kernel
    # Eligibility (paper §3.1): target must not overflow U; own cluster
    # always allowed. Note the snapshot semantics: sizes do not include
    # v's own pending departure — identical to the paper's rule of
    # checking the *target* bound only.
    eligible = (sizes[None, :] + node_w[:, None]) <= upper
    eligible = eligible | (onehot > 0)
    neg = jnp.asarray(jnp.finfo(adj.dtype).min / 2, adj.dtype)
    masked = jnp.where(eligible, scores, neg)
    best = jnp.argmax(masked, axis=1).astype(jnp.int32)
    stay = jnp.take_along_axis(scores, labels[:, None].astype(jnp.int32), axis=1)[:, 0]
    gain = jnp.max(masked, axis=1) - stay
    return best, gain


def lpa_round_spec(n: int, c: int):
    """ShapeDtypeStructs for lowering `lpa_round` at shape (N, C)."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n, n), f32),  # adj
        jax.ShapeDtypeStruct((n,), jnp.int32),  # labels
        jax.ShapeDtypeStruct((c,), f32),  # sizes
        jax.ShapeDtypeStruct((n,), f32),  # node_w
        jax.ShapeDtypeStruct((), f32),  # upper
    )
